//! Criterion-like micro/macro benchmark harness (the vendor set has no
//! criterion).  Each `cargo bench` target builds a [`Bench`] and registers
//! benchmark functions; the harness warms up, runs timed iterations,
//! reports mean/σ/percentiles with MAD-based outlier counts, and writes a
//! machine-readable JSON report next to human-readable tables.
//!
//! Two benchmark flavours:
//! * [`Bench::iter`] — wall-clock timing of a closure (runtime hot paths).
//! * [`Bench::table`] — "model benches": rows of precomputed values (e.g.
//!   simulated seconds/step) printed as the paper's tables; these have no
//!   timing loop but land in the same report format.
//!
//! Besides the verbose per-bench report, [`Bench::finish`] emits a
//! compact **perf-trajectory artifact** — `target/bench-artifacts/
//! BENCH_<name>.json` with the loop config, median seconds and
//! throughput per measurement, and any named [`Bench::metric`] values
//! (cache hit rates, speedups, regression floors).  CI's fast-mode bench
//! smoke uploads these, so the repository's performance history is
//! machine-readable across PRs; `rust/benches/baselines/` holds the
//! committed floors the regression smoke checks against.

use crate::json::Json;
use crate::util::stats::{outlier_mask, Summary};
use std::collections::BTreeMap;
use std::time::Instant;

/// Configuration for the timing loop.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop early once this much total measurement time has accumulated.
    pub target_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, min_iters: 10, max_iters: 1000, target_seconds: 3.0 }
    }
}

/// One timed result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
    pub outliers: usize,
    pub samples: Vec<f64>,
    /// Items processed per call, when registered through
    /// [`Bench::throughput`] — the artifact derives items/s from it.
    pub items: Option<f64>,
}

/// The harness: collects measurements and table rows, then reports.
pub struct Bench {
    pub name: &'static str,
    pub config: BenchConfig,
    measurements: Vec<Measurement>,
    tables: Vec<Table>,
    metrics: Vec<(String, f64)>,
    t_start: Instant,
}

/// A named table of rows (each row: label + column values).
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
    /// Printed footnote (e.g. "paper reports ...").
    pub note: String,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            note: String::new(),
        }
    }

    pub fn row(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), values));
    }

    pub fn note(&mut self, s: &str) {
        self.note = s.to_string();
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn markdown(&self) -> String {
        let mut s = format!("### {}\n\n| |", self.title);
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push_str("\n|---|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for (label, vals) in &self.rows {
            s.push_str(&format!("| {label} |"));
            for v in vals {
                s.push_str(&format!(" {} |", fmt_val(*v)));
            }
            s.push('\n');
        }
        if !self.note.is_empty() {
            s.push_str(&format!("\n_{}_\n", self.note));
        }
        s
    }
}

fn fmt_val(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 || v.abs() < 0.01 {
        format!("{v:.3e}")
    } else {
        format!("{v:.2}")
    }
}

impl Bench {
    pub fn new(name: &'static str) -> Bench {
        // honour a quick mode for CI-style runs
        let mut config = BenchConfig::default();
        if std::env::var("SCALESTUDY_BENCH_FAST").is_ok() {
            config =
                BenchConfig { warmup_iters: 1, min_iters: 3, max_iters: 10, target_seconds: 0.3 };
        }
        println!("== bench: {name} ==");
        Bench {
            name,
            config,
            measurements: Vec::new(),
            tables: Vec::new(),
            metrics: Vec::new(),
            t_start: Instant::now(),
        }
    }

    /// Time `f` (seconds per call) under the configured loop.
    pub fn iter<F: FnMut()>(&mut self, name: &str, mut f: F) {
        for _ in 0..self.config.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let loop_start = Instant::now();
        while samples.len() < self.config.max_iters
            && (samples.len() < self.config.min_iters
                || loop_start.elapsed().as_secs_f64() < self.config.target_seconds)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let summary = Summary::of(&samples);
        let outliers = outlier_mask(&samples, 5.0).iter().filter(|&&b| b).count();
        println!(
            "  {name:<40} mean {:>12} σ {:>10} p50 {:>12} p99 {:>12} (n={}, outliers={})",
            crate::util::human_time(summary.mean),
            crate::util::human_time(summary.std),
            crate::util::human_time(summary.p50),
            crate::util::human_time(summary.p99),
            summary.n,
            outliers
        );
        self.measurements.push(Measurement {
            name: name.to_string(),
            summary,
            outliers,
            samples,
            items: None,
        });
    }

    /// Time `f` which processes `items` items per call; also reports
    /// throughput (items/s) and records it in the perf artifact.
    pub fn throughput<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) {
        self.iter(name, &mut f);
        let m = self.measurements.last_mut().unwrap();
        m.items = Some(items);
        println!(
            "  {name:<40} throughput {:.1} items/s",
            items / m.summary.mean
        );
    }

    /// Record a named scalar (a cache hit rate, a speedup factor, a
    /// points/s throughput measured outside the timing loop) into the
    /// perf-trajectory artifact.
    pub fn metric(&mut self, name: &str, value: f64) {
        println!("  metric {name:<33} {value:.4}");
        self.metrics.push((name.to_string(), value));
    }

    /// Register a finished table.
    pub fn table(&mut self, t: Table) {
        println!("{}", t.markdown());
        self.tables.push(t);
    }

    /// Write the JSON report and finish. Conventional call at the end of
    /// every bench target's `main`.
    pub fn finish(self) {
        let dir = std::path::Path::new("target/bench-reports");
        let _ = std::fs::create_dir_all(dir);
        let mut obj = BTreeMap::new();
        obj.insert("bench".to_string(), Json::Str(self.name.to_string()));
        obj.insert(
            "wall_seconds".to_string(),
            Json::Num(self.t_start.elapsed().as_secs_f64()),
        );
        let meas: Vec<Json> = self
            .measurements
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::Str(m.name.clone())),
                    ("mean_s", Json::Num(m.summary.mean)),
                    ("std_s", Json::Num(m.summary.std)),
                    ("p50_s", Json::Num(m.summary.p50)),
                    ("p90_s", Json::Num(m.summary.p90)),
                    ("p99_s", Json::Num(m.summary.p99)),
                    ("n", Json::Num(m.summary.n as f64)),
                    ("outliers", Json::Num(m.outliers as f64)),
                ])
            })
            .collect();
        obj.insert("measurements".to_string(), Json::Arr(meas));
        let tables: Vec<Json> = self
            .tables
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("title", Json::Str(t.title.clone())),
                    (
                        "columns",
                        Json::Arr(t.columns.iter().map(|c| Json::Str(c.clone())).collect()),
                    ),
                    (
                        "rows",
                        Json::Arr(
                            t.rows
                                .iter()
                                .map(|(l, v)| {
                                    Json::obj(vec![
                                        ("label", Json::Str(l.clone())),
                                        ("values", Json::from_f64_slice(v)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        obj.insert("tables".to_string(), Json::Arr(tables));
        let path = dir.join(format!("{}.json", self.name));
        if let Err(e) = std::fs::write(&path, Json::Obj(obj).pretty()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("report: {}", path.display());
        }

        // ---- the compact perf-trajectory artifact (BENCH_<name>.json)
        let artifact = self.artifact_json();
        let art_path =
            std::path::Path::new("target/bench-artifacts").join(format!("BENCH_{}.json", self.name));
        if let Err(e) = artifact.write_file(&art_path) {
            eprintln!("warning: could not write {}: {e:#}", art_path.display());
        } else {
            println!("artifact: {}", art_path.display());
        }
    }

    /// The machine-readable perf artifact: bench name, loop config,
    /// per-measurement median seconds (+ items/s where registered), and
    /// every [`Bench::metric`].
    fn artifact_json(&self) -> Json {
        let meas: Vec<Json> = self
            .measurements
            .iter()
            .map(|m| {
                let mut fields = vec![
                    ("name", Json::Str(m.name.clone())),
                    ("median_s", Json::Num(m.summary.p50)),
                    ("mean_s", Json::Num(m.summary.mean)),
                    ("n", Json::Num(m.summary.n as f64)),
                ];
                if let Some(items) = m.items {
                    fields.push(("items_per_s", Json::Num(items / m.summary.mean)));
                }
                Json::obj(fields)
            })
            .collect();
        let metrics: Vec<Json> = self
            .metrics
            .iter()
            .map(|(k, v)| {
                Json::obj(vec![("name", Json::Str(k.clone())), ("value", Json::Num(*v))])
            })
            .collect();
        Json::obj(vec![
            ("bench", Json::Str(self.name.to_string())),
            (
                "config",
                Json::obj(vec![
                    ("warmup_iters", Json::Num(self.config.warmup_iters as f64)),
                    ("min_iters", Json::Num(self.config.min_iters as f64)),
                    ("max_iters", Json::Num(self.config.max_iters as f64)),
                    ("target_seconds", Json::Num(self.config.target_seconds)),
                    (
                        "fast_mode",
                        Json::Bool(std::env::var("SCALESTUDY_BENCH_FAST").is_ok()),
                    ),
                ]),
            ),
            ("wall_seconds", Json::Num(self.t_start.elapsed().as_secs_f64())),
            ("measurements", Json::Arr(meas)),
            ("metrics", Json::Arr(metrics)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iter_collects_min_iters() {
        std::env::set_var("SCALESTUDY_BENCH_FAST", "1");
        let mut b = Bench::new("selftest");
        let mut counter = 0u64;
        b.iter("noop", || counter += 1);
        assert!(counter >= 3);
        assert_eq!(b.measurements.len(), 1);
        assert!(b.measurements[0].summary.mean >= 0.0);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Table 1", &["2", "4", "8"]);
        t.row("stage 2", vec![20.38, 12.0, 31.42]);
        t.row("stage 3", vec![25.78, 23.25, 38.86]);
        t.note("seconds per step");
        let md = t.markdown();
        assert!(md.contains("| stage 2 | 20.38 | 12.00 | 31.42 |"));
        assert!(md.contains("seconds per step"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row("r", vec![1.0]);
    }

    /// Satellite: the perf-trajectory artifact carries the loop config,
    /// per-measurement medians + throughput, and named metrics.  (The
    /// loop config is pinned directly — mutating the fast-mode env var
    /// from a multi-threaded test binary races other tests' reads.)
    #[test]
    fn artifact_json_records_measurements_and_metrics() {
        let mut b = Bench::new("artifact-selftest");
        b.config =
            BenchConfig { warmup_iters: 1, min_iters: 3, max_iters: 5, target_seconds: 0.05 };
        let mut c = 0u64;
        b.throughput("tick", 10.0, || c += 1);
        b.metric("hit_rate", 0.75);
        let j = b.artifact_json();
        assert_eq!(j.get("bench").as_str(), Some("artifact-selftest"));
        assert_eq!(j.get("config").get("max_iters").as_usize(), Some(5));
        let meas = j.get("measurements").as_arr().unwrap();
        assert_eq!(meas.len(), 1);
        assert_eq!(meas[0].get("name").as_str(), Some("tick"));
        assert!(meas[0].get("median_s").as_f64().unwrap() >= 0.0);
        assert!(meas[0].get("items_per_s").as_f64().unwrap() > 0.0);
        let metrics = j.get("metrics").as_arr().unwrap();
        assert_eq!(metrics.len(), 1);
        assert_eq!(metrics[0].get("name").as_str(), Some("hit_rate"));
        assert_eq!(metrics[0].get("value").as_f64(), Some(0.75));
    }
}
