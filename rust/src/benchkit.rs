//! Criterion-like micro/macro benchmark harness (the vendor set has no
//! criterion).  Each `cargo bench` target builds a [`Bench`] and registers
//! benchmark functions; the harness warms up, runs timed iterations,
//! reports mean/σ/percentiles with MAD-based outlier counts, and writes a
//! machine-readable JSON report next to human-readable tables.
//!
//! Two benchmark flavours:
//! * [`Bench::iter`] — wall-clock timing of a closure (runtime hot paths).
//! * [`Bench::table`] — "model benches": rows of precomputed values (e.g.
//!   simulated seconds/step) printed as the paper's tables; these have no
//!   timing loop but land in the same report format.

use crate::json::Json;
use crate::util::stats::{outlier_mask, Summary};
use std::collections::BTreeMap;
use std::time::Instant;

/// Configuration for the timing loop.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop early once this much total measurement time has accumulated.
    pub target_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, min_iters: 10, max_iters: 1000, target_seconds: 3.0 }
    }
}

/// One timed result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
    pub outliers: usize,
    pub samples: Vec<f64>,
}

/// The harness: collects measurements and table rows, then reports.
pub struct Bench {
    pub name: &'static str,
    pub config: BenchConfig,
    measurements: Vec<Measurement>,
    tables: Vec<Table>,
    t_start: Instant,
}

/// A named table of rows (each row: label + column values).
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
    /// Printed footnote (e.g. "paper reports ...").
    pub note: String,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            note: String::new(),
        }
    }

    pub fn row(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), values));
    }

    pub fn note(&mut self, s: &str) {
        self.note = s.to_string();
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn markdown(&self) -> String {
        let mut s = format!("### {}\n\n| |", self.title);
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push_str("\n|---|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for (label, vals) in &self.rows {
            s.push_str(&format!("| {label} |"));
            for v in vals {
                s.push_str(&format!(" {} |", fmt_val(*v)));
            }
            s.push('\n');
        }
        if !self.note.is_empty() {
            s.push_str(&format!("\n_{}_\n", self.note));
        }
        s
    }
}

fn fmt_val(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 || v.abs() < 0.01 {
        format!("{v:.3e}")
    } else {
        format!("{v:.2}")
    }
}

impl Bench {
    pub fn new(name: &'static str) -> Bench {
        // honour a quick mode for CI-style runs
        let mut config = BenchConfig::default();
        if std::env::var("SCALESTUDY_BENCH_FAST").is_ok() {
            config =
                BenchConfig { warmup_iters: 1, min_iters: 3, max_iters: 10, target_seconds: 0.3 };
        }
        println!("== bench: {name} ==");
        Bench {
            name,
            config,
            measurements: Vec::new(),
            tables: Vec::new(),
            t_start: Instant::now(),
        }
    }

    /// Time `f` (seconds per call) under the configured loop.
    pub fn iter<F: FnMut()>(&mut self, name: &str, mut f: F) {
        for _ in 0..self.config.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let loop_start = Instant::now();
        while samples.len() < self.config.max_iters
            && (samples.len() < self.config.min_iters
                || loop_start.elapsed().as_secs_f64() < self.config.target_seconds)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let summary = Summary::of(&samples);
        let outliers = outlier_mask(&samples, 5.0).iter().filter(|&&b| b).count();
        println!(
            "  {name:<40} mean {:>12} σ {:>10} p50 {:>12} p99 {:>12} (n={}, outliers={})",
            crate::util::human_time(summary.mean),
            crate::util::human_time(summary.std),
            crate::util::human_time(summary.p50),
            crate::util::human_time(summary.p99),
            summary.n,
            outliers
        );
        self.measurements.push(Measurement { name: name.to_string(), summary, outliers, samples });
    }

    /// Time `f` which processes `items` items per call; also reports
    /// throughput (items/s).
    pub fn throughput<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) {
        self.iter(name, &mut f);
        let m = self.measurements.last().unwrap();
        println!(
            "  {name:<40} throughput {:.1} items/s",
            items / m.summary.mean
        );
    }

    /// Register a finished table.
    pub fn table(&mut self, t: Table) {
        println!("{}", t.markdown());
        self.tables.push(t);
    }

    /// Write the JSON report and finish. Conventional call at the end of
    /// every bench target's `main`.
    pub fn finish(self) {
        let dir = std::path::Path::new("target/bench-reports");
        let _ = std::fs::create_dir_all(dir);
        let mut obj = BTreeMap::new();
        obj.insert("bench".to_string(), Json::Str(self.name.to_string()));
        obj.insert(
            "wall_seconds".to_string(),
            Json::Num(self.t_start.elapsed().as_secs_f64()),
        );
        let meas: Vec<Json> = self
            .measurements
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::Str(m.name.clone())),
                    ("mean_s", Json::Num(m.summary.mean)),
                    ("std_s", Json::Num(m.summary.std)),
                    ("p50_s", Json::Num(m.summary.p50)),
                    ("p90_s", Json::Num(m.summary.p90)),
                    ("p99_s", Json::Num(m.summary.p99)),
                    ("n", Json::Num(m.summary.n as f64)),
                    ("outliers", Json::Num(m.outliers as f64)),
                ])
            })
            .collect();
        obj.insert("measurements".to_string(), Json::Arr(meas));
        let tables: Vec<Json> = self
            .tables
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("title", Json::Str(t.title.clone())),
                    (
                        "columns",
                        Json::Arr(t.columns.iter().map(|c| Json::Str(c.clone())).collect()),
                    ),
                    (
                        "rows",
                        Json::Arr(
                            t.rows
                                .iter()
                                .map(|(l, v)| {
                                    Json::obj(vec![
                                        ("label", Json::Str(l.clone())),
                                        ("values", Json::from_f64_slice(v)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        obj.insert("tables".to_string(), Json::Arr(tables));
        let path = dir.join(format!("{}.json", self.name));
        if let Err(e) = std::fs::write(&path, Json::Obj(obj).pretty()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("report: {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iter_collects_min_iters() {
        std::env::set_var("SCALESTUDY_BENCH_FAST", "1");
        let mut b = Bench::new("selftest");
        let mut counter = 0u64;
        b.iter("noop", || counter += 1);
        assert!(counter >= 3);
        assert_eq!(b.measurements.len(), 1);
        assert!(b.measurements[0].summary.mean >= 0.0);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Table 1", &["2", "4", "8"]);
        t.row("stage 2", vec![20.38, 12.0, 31.42]);
        t.row("stage 3", vec![25.78, 23.25, 38.86]);
        t.note("seconds per step");
        let md = t.markdown();
        assert!(md.contains("| stage 2 | 20.38 | 12.00 | 31.42 |"));
        assert!(md.contains("seconds per step"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row("r", vec![1.0]);
    }
}
