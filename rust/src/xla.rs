//! API-compatible stub of the `xla` PJRT bindings.
//!
//! The offline vendor set does not carry the real `xla` crate (C++ XLA/PJRT
//! FFI), so this module provides the exact surface [`crate::runtime`] uses:
//! client construction succeeds (pure bookkeeping like [`crate::runtime::Manifest`]
//! parsing, trainer plumbing and checkpointing all work and are tested), while
//! any attempt to parse/compile/execute an HLO artifact returns a clear
//! "built without PJRT" error.  Building with `--features pjrt` is reserved
//! for environments that link the real bindings (ROADMAP open item); the
//! artifact-driven integration tests are gated on that feature.

use std::fmt;

/// Error type mirroring the real bindings' displayable error.
#[derive(Clone, Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT runtime unavailable: built without the real `xla` bindings \
         (offline stub; see rust/src/xla.rs and the `pjrt` feature)"
            .to_string(),
    )
}

/// Parsed HLO module text.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// The PJRT client. Construction succeeds so artifact-independent code
/// paths (manifest loading, error reporting for missing artifacts) work.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (offline stub, no PJRT)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

/// A compiled executable (never constructible through the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

/// A device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

/// A host-side tensor literal. The stub tracks shape/size only; data never
/// round-trips because nothing can execute.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    bytes: usize,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal over a native element slice.
    pub fn vec1<T: Copy>(data: &[T]) -> Literal {
        Literal { bytes: std::mem::size_of::<T>() * data.len(), dims: vec![data.len() as i64] }
    }

    /// 0-D scalar literal.
    pub fn scalar<T: Copy>(_value: T) -> Literal {
        Literal { bytes: std::mem::size_of::<T>(), dims: Vec::new() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal { bytes: self.bytes, dims: dims.to_vec() })
    }

    /// Refresh the literal's contents in place (accepted and discarded:
    /// execution is impossible through the stub).
    pub fn copy_raw_from<T: Copy>(&mut self, _src: &[T]) -> Result<(), XlaError> {
        Ok(())
    }

    pub fn copy_raw_to<T: Copy>(&self, _dst: &mut [T]) -> Result<(), XlaError> {
        Err(unavailable())
    }

    pub fn get_first_element<T: Copy>(&self) -> Result<T, XlaError> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }

    /// Shape accessor (handy for debugging the stub).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Size in bytes tracked for this literal.
    pub fn size_bytes(&self) -> usize {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_cannot_execute() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = PjRtClient::cpu().unwrap().compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn literal_shape_bookkeeping() {
        let l = Literal::vec1(&[1.0f32; 12]).reshape(&[3, 4]).unwrap();
        assert_eq!(l.dims(), &[3, 4]);
        assert_eq!(l.size_bytes(), 48);
        let mut l = l;
        l.copy_raw_from(&[0.0f32; 12]).unwrap();
        assert!(l.get_first_element::<f32>().is_err());
    }
}
