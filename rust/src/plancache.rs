//! Persistent cache of **whole planning answers**: the cross-query layer
//! of the incremental-planning stack (the per-step [`crate::sweep::SimCache`]
//! memoizes simulator pricings; this memoizes the search *result*).
//!
//! A planner query is fully determined by (model, cluster — including
//! heterogeneous extra groups —, workload, plan space, objective
//! parameters): the branch-and-bound search is deterministic and
//! bit-identical across worker counts, so the same query always produces
//! the same [`crate::planner::PlanResult`].  [`PlanKey::of`] canonicalizes
//! every one of those fields (floats as exact bit patterns, variable-length
//! lists length-prefixed so adjacent fields can never alias) and the cache
//! maps it to a [`CachedPlan`]: the best point and full frontier stored as
//! **compact plan coordinates** plus their priced [`StepTime`]s, with the
//! `evaluated`/`feasible`/`space_size` counters.  A warm repeat `plan`
//! query is then an O(1) lookup + a cheap re-materialization — no
//! enumeration, no bounds, no simulation.
//!
//! Materialization is bit-identical by construction: every non-swept knob
//! of a planner setup is fixed ([`crate::planner`] builds each candidate
//! through one shared constructor), so the stored coordinates
//! (nodes, dp/tp/pp/sp/ep, stage, optimizer, schedule, offload, cap)
//! rebuild the exact [`TrainSetup`] the search priced, and the stored
//! [`StepTime`] carries the exact bits the simulator produced.
//!
//! Mechanics mirror the SimCache deliberately: 16 lock stripes, exact
//! hit/miss counters, insertion-order (oldest-first) eviction under a
//! bound (`SCALESTUDY_PLANCACHE_MAX`, 0 = unbounded), schema-arbitrated
//! persistence to `target/pallas_plancache.json` (override with
//! `SCALESTUDY_PLANCACHE`) with every float as its bit pattern, and union
//! [`PlanCache::merge`] where existing entries win.  On top of that it
//! tracks `evictions` and a `resident_weight` (total stored plan points)
//! in the style of the skeleton cache's stats, so the `cache` CLI and the
//! serve `stats` query can report all three caches side by side.

use crate::hardware::ClusterSpec;
use crate::json::Json;
use crate::model::ModelCfg;
use crate::objective::Objective;
use crate::parallel::ParallelCfg;
use crate::planner::{PlanPoint, PlanResult, PlanSpace};
use crate::sim::{StepTime, Workload};
use crate::sweep::{env_usize_or, hex_u64, parse_hex_u64, step_from_json, step_to_json};
use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// On-disk schema version.  Bump whenever [`PlanKey`] layout, the stored
/// plan-coordinate set, or anything that feeds the planner's pricing
/// changes; files under any other version load empty (a stale plan must
/// never survive a pricing change — a cold start merely re-searches).
pub const PLANCACHE_SCHEMA_VERSION: u64 = 2;

/// Default bound on resident plans.  Whole plan results are much heavier
/// than single step pricings (a frontier can hold dozens of points), so
/// the default sits far below the SimCache's; override with
/// `SCALESTUDY_PLANCACHE_MAX` (0 = unbounded).
pub const PLANCACHE_DEFAULT_MAX_ENTRIES: usize = 4096;

fn default_max_entries() -> usize {
    env_usize_or("SCALESTUDY_PLANCACHE_MAX", PLANCACHE_DEFAULT_MAX_ENTRIES)
}

/// Lock stripes for the plan map (same contention argument as the
/// SimCache: concurrent serve waves only collide 1/16 of the time).
const PLANCACHE_STRIPES: usize = 16;

/// Canonical key of one planning query: every input that can change the
/// answer, floats as exact bit patterns.  Variable-length sections
/// (extra node groups, the plan-space lists) are length-prefixed so two
/// different queries can never flatten to the same field vector.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlanKey {
    model_name: String,
    objective: &'static str,
    fields: Vec<u64>,
}

impl PlanKey {
    pub fn of(
        model: &ModelCfg,
        cluster: &ClusterSpec,
        workload: &Workload,
        space: &PlanSpace,
        objective: &Objective,
    ) -> PlanKey {
        let mut f: Vec<u64> = Vec::new();
        // ---- model
        f.extend_from_slice(&[
            model.vocab,
            model.d_model,
            model.d_ff,
            model.num_heads,
            model.d_kv,
            model.enc_layers,
            model.dec_layers,
            model.tied_lm_head as u64,
            model.experts,
            model.top_k,
            model.moe_every,
        ]);
        // ---- cluster (primary group, fabric, storage, then every extra
        // group — same field set the SimCache's SetupKey canonicalizes)
        f.extend_from_slice(&[
            cluster.nodes as u64,
            cluster.node.gpus as u64,
            cluster.node.gpu.peak_flops_bf16.to_bits(),
            cluster.node.gpu.peak_flops_fp32.to_bits(),
            cluster.node.gpu.hbm_bytes.to_bits(),
            cluster.node.gpu.hbm_bw.to_bits(),
            cluster.node.gpu.achievable_frac.to_bits(),
            cluster.node.nvlink_bw.to_bits(),
            cluster.node.nvlink_latency.to_bits(),
            cluster.node.host_ram_bytes.to_bits(),
            cluster.node.pcie_bw.to_bits(),
            cluster.ib_bw.to_bits(),
            cluster.ib_latency.to_bits(),
            cluster.oversub_threshold_nodes as u64,
            cluster.oversub_factor.to_bits(),
            cluster.storage_samples_per_s.to_bits(),
            cluster.storage_threshold_nodes as u64,
            cluster.storage_contention.to_bits(),
        ]);
        f.push(cluster.extra_groups.len() as u64);
        for g in &cluster.extra_groups {
            f.extend_from_slice(&[
                g.nodes as u64,
                g.node.gpus as u64,
                g.node.gpu.peak_flops_bf16.to_bits(),
                g.node.gpu.peak_flops_fp32.to_bits(),
                g.node.gpu.hbm_bytes.to_bits(),
                g.node.gpu.hbm_bw.to_bits(),
                g.node.gpu.achievable_frac.to_bits(),
                g.node.nvlink_bw.to_bits(),
                g.node.nvlink_latency.to_bits(),
                g.node.host_ram_bytes.to_bits(),
                g.node.pcie_bw.to_bits(),
                g.ib_bw.to_bits(),
            ]);
        }
        // Blast-domain topology re-ranks Goodput plans, so it is part of
        // the key even though it never changes failure-free step times.
        f.push(cluster.domains.len() as u64);
        for d in &cluster.domains {
            f.push(d.size as u64);
            f.push(d.mtbf_hours.to_bits());
        }
        // ---- workload
        f.extend_from_slice(&[
            workload.global_batch as u64,
            workload.enc_len,
            workload.dec_len,
            workload.ckpt as u64,
        ]);
        // ---- plan space (every list length-prefixed)
        f.push(space.stages.len() as u64);
        f.extend(space.stages.iter().map(|s| s.index() as u64));
        f.push(space.optimizers.len() as u64);
        f.extend(space.optimizers.iter().map(|&o| o as u64));
        f.push(space.offload.len() as u64);
        f.extend(space.offload.iter().map(|&o| o as u64));
        f.push(space.micro_batch_caps.len() as u64);
        f.extend(space.micro_batch_caps.iter().map(|&c| c as u64));
        f.push(space.schedules.len() as u64);
        f.extend(space.schedules.iter().map(|&s| s as u64));
        f.push(space.nodes.len() as u64);
        f.extend(space.nodes.iter().map(|&n| n as u64));
        f.extend_from_slice(&[
            space.max_tp as u64,
            space.max_pp as u64,
            space.max_sp as u64,
            space.max_ep as u64,
        ]);
        // ---- objective parameters (the discriminant rides as the
        // `objective` name string)
        match objective {
            Objective::StepTime => {}
            Objective::Goodput(fm) => {
                f.extend_from_slice(&[
                    fm.mtbf_hours.to_bits(),
                    fm.write_bw.to_bits(),
                    fm.read_bw.to_bits(),
                    fm.shared_bw.to_bits(),
                    fm.restart_overhead_s.to_bits(),
                ]);
                // Checkpoint policy: discriminant + a fixed-width slot
                // per parameter (zeros for the variants that lack one).
                let (disc, a, b, c) = match fm.policy {
                    crate::resilience::CheckpointPolicy::Sync => (0u64, 0u64, 0u64, 0u64),
                    crate::resilience::CheckpointPolicy::Async { snapshot_s, drain_bw } => {
                        (1, snapshot_s.to_bits(), drain_bw.to_bits(), 0)
                    }
                    crate::resilience::CheckpointPolicy::Tiered {
                        local_bw,
                        shared_bw,
                        replicate,
                    } => (2, local_bw.to_bits(), shared_bw.to_bits(), replicate as u64),
                };
                f.extend_from_slice(&[disc, a, b, c]);
            }
            Objective::CostToTarget(c) => {
                f.extend_from_slice(&[
                    c.target_loss.to_bits(),
                    c.node_cost_per_hour.to_bits(),
                    c.inputs.lr.to_bits(),
                    c.inputs.warmup_steps.to_bits(),
                    c.inputs.global_batch as u64,
                    c.inputs.tokens_per_sample,
                    c.inputs.opt as u64,
                    c.inputs.weight_decay.to_bits(),
                    c.inputs.dropout.to_bits(),
                    c.inputs.grad_clip.to_bits(),
                    c.inputs.label_smoothing.to_bits(),
                    c.inputs.full_precision as u64,
                ]);
            }
        }
        PlanKey { model_name: model.name.clone(), objective: objective.name(), fields: f }
    }
}

/// One stored plan point: the swept coordinates plus the exact priced
/// [`StepTime`].  Everything else about the setup is a planner-fixed
/// knob, so [`PointRec::materialize`] rebuilds the identical
/// [`crate::sim::TrainSetup`] through the planner's own constructor.
#[derive(Clone, Debug)]
pub struct PointRec {
    pub nodes: usize,
    pub par: ParallelCfg,
    pub stage: usize,
    pub opt: u64,
    pub sched: u64,
    pub offload: bool,
    pub cap: usize,
    pub step: StepTime,
}

fn opt_from_u64(x: u64) -> Option<crate::zero::OptimizerKind> {
    use crate::zero::OptimizerKind::*;
    match x {
        0 => Some(AdamW),
        1 => Some(SgdMomentum),
        2 => Some(Adafactor),
        3 => Some(Lamb),
        _ => None,
    }
}

fn sched_from_u64(x: u64) -> Option<crate::parallel::PipeSchedule> {
    use crate::parallel::PipeSchedule::*;
    match x {
        0 => Some(GPipe),
        1 => Some(OneFOneB),
        2 => Some(Interleaved1F1B),
        _ => None,
    }
}

impl PointRec {
    pub fn of(p: &PlanPoint) -> PointRec {
        let s = &p.setup;
        PointRec {
            nodes: s.cluster.total_nodes(),
            par: s.par,
            stage: s.stage.index(),
            opt: s.opt as u64,
            sched: s.sched as u64,
            offload: s.offload,
            cap: s.micro_batch_cap,
            step: p.step.clone(),
        }
    }

    /// Rebuild the exact plan point for the query this record was stored
    /// under.  `None` only on a malformed record (unknown enum index) —
    /// treated as a cache miss by the caller.
    pub fn materialize(
        &self,
        model: &ModelCfg,
        cluster: &ClusterSpec,
        workload: &Workload,
    ) -> Option<PlanPoint> {
        let stage = crate::zero::ZeroStage::from_index(self.stage)?;
        let opt = opt_from_u64(self.opt)?;
        let sched = sched_from_u64(self.sched)?;
        let sub = cluster.take_nodes(self.nodes);
        let setup = crate::planner::branch_setup(
            model, &sub, workload, self.par, stage, opt, sched, self.offload, self.cap,
        );
        Some(PlanPoint { setup, step: self.step.clone() })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::Num(self.nodes as f64)),
            ("dp", Json::Num(self.par.dp as f64)),
            ("tp", Json::Num(self.par.tp as f64)),
            ("pp", Json::Num(self.par.pp as f64)),
            ("sp", Json::Num(self.par.sp as f64)),
            ("ep", Json::Num(self.par.ep as f64)),
            ("stage", Json::Num(self.stage as f64)),
            ("opt", Json::Num(self.opt as f64)),
            ("sched", Json::Num(self.sched as f64)),
            ("offload", Json::Bool(self.offload)),
            ("cap", Json::Num(self.cap as f64)),
            ("step", step_to_json(&self.step)),
        ])
    }

    fn from_json(j: &Json) -> Option<PointRec> {
        Some(PointRec {
            nodes: j.get("nodes").as_usize()?,
            par: ParallelCfg {
                dp: j.get("dp").as_usize()?,
                tp: j.get("tp").as_usize()?,
                pp: j.get("pp").as_usize()?,
                sp: j.get("sp").as_usize()?,
                ep: j.get("ep").as_usize()?,
            },
            stage: j.get("stage").as_usize()?,
            opt: j.get("opt").as_usize()? as u64,
            sched: j.get("sched").as_usize()? as u64,
            offload: j.get("offload").as_bool()?,
            cap: j.get("cap").as_usize()?,
            step: step_from_json(j.get("step"))?,
        })
    }
}

/// A complete stored planning answer.
#[derive(Clone, Debug)]
pub struct CachedPlan {
    pub best: Option<PointRec>,
    pub frontier: Vec<PointRec>,
    pub evaluated: usize,
    pub feasible: usize,
    pub space_size: usize,
}

impl CachedPlan {
    pub fn of(r: &PlanResult) -> CachedPlan {
        CachedPlan {
            best: r.best.as_ref().map(PointRec::of),
            frontier: r.frontier.iter().map(PointRec::of).collect(),
            evaluated: r.evaluated,
            feasible: r.feasible,
            space_size: r.space_size,
        }
    }

    /// Rebuild the full [`PlanResult`] for the same query inputs the
    /// entry was keyed under.  `None` on a malformed record.
    pub fn materialize(
        &self,
        model: &ModelCfg,
        cluster: &ClusterSpec,
        workload: &Workload,
    ) -> Option<PlanResult> {
        let best = match &self.best {
            Some(rec) => Some(rec.materialize(model, cluster, workload)?),
            None => None,
        };
        let mut frontier = Vec::with_capacity(self.frontier.len());
        for rec in &self.frontier {
            frontier.push(rec.materialize(model, cluster, workload)?);
        }
        Some(PlanResult {
            best,
            frontier,
            evaluated: self.evaluated,
            feasible: self.feasible,
            space_size: self.space_size,
        })
    }

    /// Stored plan points in this entry (the resident-weight unit).
    fn weight(&self) -> usize {
        self.frontier.len() + self.best.is_some() as usize
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("evaluated", Json::Num(self.evaluated as f64)),
            ("feasible", Json::Num(self.feasible as f64)),
            ("space_size", Json::Num(self.space_size as f64)),
            (
                "best",
                match &self.best {
                    Some(rec) => rec.to_json(),
                    None => Json::Null,
                },
            ),
            ("frontier", Json::Arr(self.frontier.iter().map(|r| r.to_json()).collect())),
        ])
    }

    fn from_json(j: &Json) -> Option<CachedPlan> {
        let best = match j.get("best") {
            Json::Null => None,
            rec => Some(PointRec::from_json(rec)?),
        };
        let frontier: Option<Vec<PointRec>> =
            j.get("frontier").as_arr()?.iter().map(PointRec::from_json).collect();
        Some(CachedPlan {
            best,
            frontier: frontier?,
            evaluated: j.get("evaluated").as_usize()?,
            feasible: j.get("feasible").as_usize()?,
            space_size: j.get("space_size").as_usize()?,
        })
    }
}

/// Thread-safe, bounded, persistent map `PlanKey → CachedPlan` (module
/// docs).  Lookup/insert take exactly one stripe-lock acquisition on the
/// hot path; eviction pops the globally oldest-inserted entry.
pub struct PlanCache {
    stripes: Vec<Mutex<HashMap<PlanKey, (CachedPlan, u64)>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    entries: AtomicUsize,
    evictions: AtomicUsize,
    /// Total stored plan points across every entry (frontier members +
    /// bests) — the skeleton-cache-style weight the stats report.
    weight: AtomicUsize,
    seq: AtomicU64,
    /// Keys in insertion order (seq assigned under this lock, so queue
    /// order == age order); same stripe→ages nesting discipline as the
    /// SimCache, so the pair cannot deadlock.
    ages: Mutex<VecDeque<(PlanKey, u64)>>,
    max_entries: usize,
}

impl Default for PlanCache {
    fn default() -> PlanCache {
        PlanCache::new()
    }
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::with_capacity(default_max_entries())
    }

    /// A cache bounded to `max_entries` resident plans (0 = unbounded).
    pub fn with_capacity(max_entries: usize) -> PlanCache {
        PlanCache {
            stripes: (0..PLANCACHE_STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            entries: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            weight: AtomicUsize::new(0),
            seq: AtomicU64::new(0),
            ages: Mutex::new(VecDeque::new()),
            max_entries,
        }
    }

    fn stripe_of(&self, key: &PlanKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.stripes.len()
    }

    fn next_seq_and_track(&self, key: &PlanKey) -> u64 {
        let mut ages = self.ages.lock().unwrap();
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        ages.push_back((key.clone(), seq));
        seq
    }

    /// Remove the globally oldest-inserted entry (amortized O(1); stale
    /// age-queue fronts — already replaced entries — are discarded).
    fn evict_oldest(&self) {
        loop {
            let front = { self.ages.lock().unwrap().pop_front() };
            let (k, s) = match front {
                Some(f) => f,
                None => return,
            };
            let mut map = self.stripes[self.stripe_of(&k)].lock().unwrap();
            if map.get(&k).map_or(false, |&(_, cs)| cs == s) {
                if let Some((plan, _)) = map.remove(&k) {
                    self.weight.fetch_sub(plan.weight(), Ordering::Relaxed);
                }
                self.entries.fetch_sub(1, Ordering::Relaxed);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
    }

    /// The stored answer for `key`, if any (exact hit/miss counting).
    pub fn lookup(&self, key: &PlanKey) -> Option<CachedPlan> {
        let map = self.stripes[self.stripe_of(key)].lock().unwrap();
        match map.get(key) {
            Some((plan, _)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(plan.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store `plan` under `key` (an existing entry for the key is
    /// replaced in place and keeps being tracked by its newest age).
    pub fn insert(&self, key: PlanKey, plan: CachedPlan) {
        {
            let mut map = self.stripes[self.stripe_of(&key)].lock().unwrap();
            let seq = self.next_seq_and_track(&key);
            self.weight.fetch_add(plan.weight(), Ordering::Relaxed);
            if let Some((old, _)) = map.insert(key, (plan, seq)) {
                self.weight.fetch_sub(old.weight(), Ordering::Relaxed);
            } else {
                self.entries.fetch_add(1, Ordering::Relaxed);
            }
        }
        if self.max_entries > 0 && self.entries.load(Ordering::Relaxed) > self.max_entries {
            self.evict_oldest();
        }
    }

    /// Union `other`'s plans into this cache: entries already present
    /// here win; incoming entries arrive oldest-first so relative ages
    /// survive; the capacity bound applies as usual.  Returns how many
    /// entries were added.  Schema arbitration happens at load time, so
    /// merging an old-schema file is a no-op.
    pub fn merge(&self, other: &PlanCache) -> usize {
        let mut incoming: Vec<(PlanKey, CachedPlan, u64)> = Vec::new();
        for stripe in &other.stripes {
            for (k, (plan, s)) in stripe.lock().unwrap().iter() {
                incoming.push((k.clone(), plan.clone(), *s));
            }
        }
        incoming.sort_by(|a, b| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)));
        let mut added = 0usize;
        for (k, plan, _) in incoming {
            {
                let mut map = self.stripes[self.stripe_of(&k)].lock().unwrap();
                if map.contains_key(&k) {
                    continue;
                }
                let seq = self.next_seq_and_track(&k);
                self.weight.fetch_add(plan.weight(), Ordering::Relaxed);
                map.insert(k, (plan, seq));
                self.entries.fetch_add(1, Ordering::Relaxed);
                added += 1;
            }
            if self.max_entries > 0
                && self.entries.load(Ordering::Relaxed) > self.max_entries
            {
                self.evict_oldest();
            }
        }
        added
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> usize {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Total stored plan points (frontier members + bests).
    pub fn resident_weight(&self) -> usize {
        self.weight.load(Ordering::Relaxed)
    }

    /// Hit fraction of all lookups so far (0.0 when none).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits(), self.misses());
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // ------------------------------------------------- persistence

    /// Default on-disk location (override with `SCALESTUDY_PLANCACHE`).
    pub fn default_path() -> PathBuf {
        std::env::var("SCALESTUDY_PLANCACHE")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("target/pallas_plancache.json"))
    }

    /// Load the cache at [`PlanCache::default_path`] (empty on failure).
    pub fn load_default() -> PlanCache {
        PlanCache::load(&PlanCache::default_path())
    }

    /// Save to [`PlanCache::default_path`].
    pub fn save_default(&self) -> anyhow::Result<()> {
        self.save(&PlanCache::default_path())
    }

    /// Load a cache from `path`.  Any failure degrades to an empty cache;
    /// a *present but unusable* file emits a one-line stderr warning (a
    /// missing file is a normal cold start).
    pub fn load(path: &Path) -> PlanCache {
        let (cache, warning) = PlanCache::load_verbose(path);
        if let Some(w) = warning {
            eprintln!("warning: {w}");
        }
        cache
    }

    /// [`PlanCache::load`] with the degradation reason surfaced.
    pub fn load_verbose(path: &Path) -> (PlanCache, Option<String>) {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return (PlanCache::new(), None);
            }
            Err(e) => {
                let why = format!(
                    "plan cache {}: unreadable ({e}); starting empty",
                    path.display()
                );
                return (PlanCache::new(), Some(why));
            }
        };
        let json = match Json::parse(&text) {
            Ok(j) => j,
            Err(e) => {
                let why = format!(
                    "plan cache {}: corrupt JSON ({e}); starting empty",
                    path.display()
                );
                return (PlanCache::new(), Some(why));
            }
        };
        match PlanCache::from_json(&json) {
            Some(cache) => (cache, None),
            None => {
                let why = format!(
                    "plan cache {}: schema/entry mismatch (want schema {PLANCACHE_SCHEMA_VERSION}); starting empty",
                    path.display()
                );
                (PlanCache::new(), Some(why))
            }
        }
    }

    /// Serialize and write atomically (temp file + rename; parents
    /// created), same durability contract as the SimCache.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        self.to_json().write_file(path)
    }

    /// The full map as a versioned JSON tree, entries sorted by key and
    /// insertion sequences densified to ranks so the eviction order
    /// survives a save/load round trip.
    pub fn to_json(&self) -> Json {
        let mut entries: Vec<(PlanKey, CachedPlan, u64)> = Vec::new();
        for stripe in &self.stripes {
            for (k, (plan, s)) in stripe.lock().unwrap().iter() {
                entries.push((k.clone(), plan.clone(), *s));
            }
        }
        let mut by_age: Vec<usize> = (0..entries.len()).collect();
        by_age.sort_by_key(|&i| entries[i].2);
        let mut rank = vec![0u64; entries.len()];
        for (r, &i) in by_age.iter().enumerate() {
            rank[i] = r as u64;
        }
        let mut tagged: Vec<(PlanKey, CachedPlan, u64)> = entries
            .into_iter()
            .zip(rank)
            .map(|((k, plan, _), r)| (k, plan, r))
            .collect();
        tagged.sort_by(|a, b| a.0.cmp(&b.0));
        let entries: Vec<Json> = tagged
            .into_iter()
            .map(|(k, plan, r)| {
                Json::obj(vec![
                    ("model", Json::Str(k.model_name)),
                    ("objective", Json::Str(k.objective.to_string())),
                    ("fields", Json::Arr(k.fields.iter().map(|&x| hex_u64(x)).collect())),
                    ("seq", hex_u64(r)),
                    ("plan", plan.to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Num(PLANCACHE_SCHEMA_VERSION as f64)),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Rebuild from [`PlanCache::to_json`] output.  `None` on schema
    /// mismatch or any malformed entry; entries are inserted oldest-first
    /// so an over-capacity file keeps its newest plans.
    pub fn from_json(json: &Json) -> Option<PlanCache> {
        if json.get("schema").as_usize()? as u64 != PLANCACHE_SCHEMA_VERSION {
            return None;
        }
        let cache = PlanCache::new();
        let mut incoming: Vec<(PlanKey, CachedPlan, u64)> = Vec::new();
        for e in json.get("entries").as_arr()? {
            let model_name = e.get("model").as_str()?.to_string();
            let objective = match e.get("objective").as_str()? {
                "step_time" => "step_time",
                "goodput" => "goodput",
                "cost_to_target" => "cost_to_target",
                _ => return None,
            };
            let fields: Option<Vec<u64>> =
                e.get("fields").as_arr()?.iter().map(parse_hex_u64).collect();
            let key = PlanKey { model_name, objective, fields: fields? };
            let plan = CachedPlan::from_json(e.get("plan"))?;
            let age = parse_hex_u64(e.get("seq"))?;
            incoming.push((key, plan, age));
        }
        incoming.sort_by(|a, b| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)));
        for (key, plan, _) in incoming {
            cache.insert(key, plan);
        }
        Some(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;
    use crate::objective::CostToTarget;
    use crate::planner;
    use crate::resilience::FailureModel;
    use crate::sweep::{SimCache, Sweep};
    use crate::zero::{OptimizerKind, ZeroStage};

    fn small_space() -> PlanSpace {
        PlanSpace {
            stages: ZeroStage::all().to_vec(),
            optimizers: vec![OptimizerKind::AdamW],
            offload: vec![false],
            micro_batch_caps: vec![0],
            schedules: vec![crate::parallel::PipeSchedule::OneFOneB],
            nodes: vec![1, 2],
            max_tp: 8,
            max_pp: 4,
            max_sp: 1,
            max_ep: 1,
        }
    }

    fn assert_results_bit_identical(a: &PlanResult, b: &PlanResult) {
        assert_eq!(a.evaluated, b.evaluated);
        assert_eq!(a.feasible, b.feasible);
        assert_eq!(a.space_size, b.space_size);
        assert_eq!(a.best.is_some(), b.best.is_some());
        if let (Some(x), Some(y)) = (&a.best, &b.best) {
            assert_eq!(x.label(), y.label());
            assert_eq!(x.seconds_per_step().to_bits(), y.seconds_per_step().to_bits());
            assert_eq!(x.step.mem_per_gpu.to_bits(), y.step.mem_per_gpu.to_bits());
        }
        assert_eq!(a.frontier.len(), b.frontier.len());
        for (x, y) in a.frontier.iter().zip(&b.frontier) {
            assert_eq!(x.label(), y.label());
            assert_eq!(x.seconds_per_step().to_bits(), y.seconds_per_step().to_bits());
            assert_eq!(x.step.mem_per_gpu.to_bits(), y.step.mem_per_gpu.to_bits());
        }
    }

    /// Store → lookup → materialize reproduces the search bit-for-bit,
    /// and a JSON round trip (the persistence path) preserves it.
    #[test]
    fn cached_plan_roundtrips_bit_identically() {
        let model = by_name("mt5-large").unwrap();
        let cluster = crate::hardware::ClusterSpec::lps_pod(2);
        let w = Workload::table1();
        let space = small_space();
        let r = planner::plan(&model, &cluster, &w, &space, &Sweep::serial(), &SimCache::new());
        let key = PlanKey::of(&model, &cluster, &w, &space, &Objective::StepTime);
        let cache = PlanCache::new();
        cache.insert(key.clone(), CachedPlan::of(&r));
        let hit = cache.lookup(&key).expect("stored entry");
        let back = hit.materialize(&model, &cluster, &w).expect("well-formed");
        assert_results_bit_identical(&r, &back);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.resident_weight(), r.frontier.len() + 1);
        // persistence: serialize, reload, materialize again
        let reloaded = PlanCache::from_json(&cache.to_json()).expect("schema matches");
        let back2 = reloaded
            .lookup(&key)
            .expect("entry survives the round trip")
            .materialize(&model, &cluster, &w)
            .expect("well-formed");
        assert_results_bit_identical(&r, &back2);
        // a wrong-schema file loads as None (schema arbitration)
        let mut j = cache.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::Num((PLANCACHE_SCHEMA_VERSION + 1) as f64));
        }
        assert!(PlanCache::from_json(&j).is_none());
    }

    /// The key separates every query input: model, cluster width, space,
    /// objective kind AND objective parameters.
    #[test]
    fn keys_distinguish_queries() {
        let a = by_name("mt5-base").unwrap();
        let b = by_name("mt5-large").unwrap();
        let c2 = crate::hardware::ClusterSpec::lps_pod(2);
        let c4 = crate::hardware::ClusterSpec::lps_pod(4);
        let w = Workload::table1();
        let space = small_space();
        let k = |m: &ModelCfg, c: &ClusterSpec, o: &Objective| PlanKey::of(m, c, &w, &space, o);
        let st = Objective::StepTime;
        assert_ne!(k(&a, &c2, &st), k(&b, &c2, &st));
        assert_ne!(k(&a, &c2, &st), k(&a, &c4, &st));
        assert_ne!(
            k(&a, &c2, &Objective::Goodput(FailureModel::with_mtbf(6.0))),
            k(&a, &c2, &Objective::Goodput(FailureModel::with_mtbf(12.0))),
        );
        assert_ne!(
            k(&a, &c2, &st),
            k(&a, &c2, &Objective::CostToTarget(CostToTarget::for_workload(2.6, 0.0, &w))),
        );
        assert_ne!(
            k(&a, &c2, &Objective::CostToTarget(CostToTarget::for_workload(2.6, 0.0, &w))),
            k(&a, &c2, &Objective::CostToTarget(CostToTarget::for_workload(2.6, 30.0, &w))),
        );
        // a different space (wider node ladder) is a different query
        let wider = PlanSpace { nodes: vec![1, 2, 4], ..small_space() };
        assert_ne!(
            PlanKey::of(&a, &c2, &w, &space, &st),
            PlanKey::of(&a, &c2, &w, &wider, &st)
        );
        // identical inputs agree
        assert_eq!(k(&a, &c2, &st), k(&a, &c2, &Objective::StepTime));
        // blast-domain topology is part of the cluster digest even when
        // failure-free step times are untouched
        let mut domained = c2.clone();
        domained.domains = vec![crate::hardware::BlastDomain {
            name: "switch".into(),
            size: 2,
            mtbf_hours: 100.0,
        }];
        assert_ne!(k(&a, &c2, &st), k(&a, &domained, &st));
        let mut wider_domain = domained.clone();
        wider_domain.domains[0].mtbf_hours = 200.0;
        assert_ne!(k(&a, &domained, &st), k(&a, &wider_domain, &st));
        // checkpoint policy is part of the Goodput objective digest
        let fm = FailureModel::with_mtbf(6.0);
        let mut async_fm = fm.clone();
        async_fm.policy =
            crate::resilience::CheckpointPolicy::Async { snapshot_s: 2.0, drain_bw: 2.0e9 };
        let mut tiered_fm = fm.clone();
        tiered_fm.policy = crate::resilience::CheckpointPolicy::Tiered {
            local_bw: 5.0e9,
            shared_bw: 1.0e8,
            replicate: true,
        };
        assert_ne!(
            k(&a, &c2, &Objective::Goodput(fm.clone())),
            k(&a, &c2, &Objective::Goodput(async_fm.clone())),
        );
        assert_ne!(
            k(&a, &c2, &Objective::Goodput(async_fm)),
            k(&a, &c2, &Objective::Goodput(tiered_fm.clone())),
        );
        let mut unreplicated = tiered_fm.clone();
        if let crate::resilience::CheckpointPolicy::Tiered { replicate, .. } =
            &mut unreplicated.policy
        {
            *replicate = false;
        }
        assert_ne!(
            k(&a, &c2, &Objective::Goodput(tiered_fm)),
            k(&a, &c2, &Objective::Goodput(unreplicated)),
        );
    }

    /// Capacity bound: oldest-inserted entries evict first, counters and
    /// resident weight stay exact, and merge honors existing-wins.
    #[test]
    fn eviction_and_merge_follow_simcache_semantics() {
        let model = by_name("mt5-small").unwrap();
        let w = Workload::table1();
        let space = small_space();
        let mk_key = |nodes: usize| {
            let c = crate::hardware::ClusterSpec::lps_pod(nodes);
            PlanKey::of(&model, &c, &w, &space, &Objective::StepTime)
        };
        let plan = CachedPlan {
            best: None,
            frontier: Vec::new(),
            evaluated: 1,
            feasible: 0,
            space_size: 1,
        };
        let cache = PlanCache::with_capacity(2);
        cache.insert(mk_key(1), plan.clone());
        cache.insert(mk_key(2), plan.clone());
        cache.insert(mk_key(3), plan.clone());
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert!(cache.lookup(&mk_key(1)).is_none(), "oldest entry must evict first");
        assert!(cache.lookup(&mk_key(2)).is_some());
        assert!(cache.lookup(&mk_key(3)).is_some());
        // merge: existing entries win, new ones come over
        let other = PlanCache::new();
        let newer =
            CachedPlan { evaluated: 99, ..plan.clone() };
        other.insert(mk_key(3), newer);
        other.insert(mk_key(4), plan.clone());
        let added = cache.merge(&other);
        assert_eq!(added, 1);
        assert_eq!(
            cache.lookup(&mk_key(3)).unwrap().evaluated,
            1,
            "existing entries must win a merge"
        );
    }
}
