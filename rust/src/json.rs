//! Minimal JSON parser/printer (the vendor set has no serde).
//!
//! Used for: AOT artifact manifests written by `python/compile/aot.py`,
//! run configuration files, and machine-readable results emitted by the
//! bench harness and HPO engine.  Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value tree (object keys kept in sorted order for determinism).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and message.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Path lookup: `j.path(&["batch", "size"])`.
    pub fn path(&self, keys: &[&str]) -> &Json {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k);
        }
        cur
    }

    // ---------------------------------------------------------- builders

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text)?)
    }

    /// Pretty-print to `path` atomically: write a sibling temp file, then
    /// rename over the target, so readers never observe a torn file even
    /// if the writer dies mid-write (the persistent SimCache depends on
    /// this).  Parent directories are created as needed.
    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
            }
        }
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, self.pretty())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            anyhow::anyhow!("renaming {} -> {}: {e}", tmp.display(), path.display())
        })?;
        Ok(())
    }

    /// Compact serialization.
    pub fn dumps(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.b.len());
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dumps())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(j.path(&["a"]).as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(*j.get("c"), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::parse("\"caf\\u00e9 ☕\"").unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
        let again = Json::parse(&j.dumps()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn dumps_roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"num":-3,"obj":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.dumps()).unwrap(), j);
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(42.0).dumps(), "42");
        assert_eq!(Json::Num(42.5).dumps(), "42.5");
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert_eq!(*Json::Num(1.0).get("x"), Json::Null);
    }

    #[test]
    fn write_file_roundtrips_and_replaces() {
        let dir = std::env::temp_dir().join(format!("scalestudy-json-{}", std::process::id()));
        let path = dir.join("nested").join("out.json");
        let j = Json::parse(r#"{"a": [1, 2], "b": "x"}"#).unwrap();
        j.write_file(&path).unwrap();
        assert_eq!(Json::parse_file(&path).unwrap(), j);
        // overwriting an existing file goes through the same rename path
        let j2 = Json::parse("[3]").unwrap();
        j2.write_file(&path).unwrap();
        assert_eq!(Json::parse_file(&path).unwrap(), j2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
