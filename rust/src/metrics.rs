//! Run metrics: step logs, CSV/JSON persistence, projections.

use crate::json::Json;
use std::io::Write;

/// One optimization step's telemetry.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f64,
    pub lr: f64,
    pub seconds: f64,
    pub tokens_per_s: f64,
}

/// A training-run log with windowed smoothing and persistence.
#[derive(Default)]
pub struct RunLog {
    pub records: Vec<StepRecord>,
    /// Free-form metadata surfaced in the JSON dump.
    pub meta: Vec<(String, String)>,
}

impl RunLog {
    pub fn new() -> RunLog {
        RunLog::default()
    }

    pub fn meta(&mut self, k: &str, v: impl ToString) {
        self.meta.push((k.to_string(), v.to_string()));
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn last_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the last `w` records.
    pub fn smoothed_loss(&self, w: usize) -> Option<f64> {
        if self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(w)..];
        Some(tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64)
    }

    /// Mean seconds/step over the last `w` records (ignoring the first
    /// record, which usually carries compile/warmup time).
    pub fn mean_step_seconds(&self, w: usize) -> Option<f64> {
        if self.records.len() < 2 {
            return None;
        }
        let body = &self.records[1..];
        let tail = &body[body.len().saturating_sub(w)..];
        Some(tail.iter().map(|r| r.seconds).sum::<f64>() / tail.len() as f64)
    }

    /// CSV dump (step,loss,lr,seconds,tokens_per_s).
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,lr,seconds,tokens_per_s")?;
        for r in &self.records {
            writeln!(
                f,
                "{},{:.6},{:.3e},{:.4},{:.1}",
                r.step, r.loss, r.lr, r.seconds, r.tokens_per_s
            )?;
        }
        Ok(())
    }

    /// JSON dump with metadata.
    pub fn to_json(&self) -> Json {
        let meta = Json::Obj(
            self.meta
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        );
        let recs = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("step", Json::Num(r.step as f64)),
                    ("loss", Json::Num(r.loss)),
                    ("lr", Json::Num(r.lr)),
                    ("seconds", Json::Num(r.seconds)),
                    ("tokens_per_s", Json::Num(r.tokens_per_s)),
                ])
            })
            .collect();
        Json::obj(vec![("meta", meta), ("records", Json::Arr(recs))])
    }

    /// Render a coarse ASCII loss curve (for terminal logs/EXPERIMENTS.md).
    pub fn ascii_loss_curve(&self, width: usize, height: usize) -> String {
        if self.records.len() < 2 || width < 2 || height < 2 {
            return String::new();
        }
        let losses: Vec<f64> = self.records.iter().map(|r| r.loss).collect();
        let (lo, hi) = losses
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &x| (a.min(x), b.max(x)));
        let span = (hi - lo).max(1e-9);
        let mut grid = vec![vec![b' '; width]; height];
        for (i, &l) in losses.iter().enumerate() {
            let x = i * (width - 1) / (losses.len() - 1);
            let y = ((hi - l) / span * (height - 1) as f64).round() as usize;
            grid[y.min(height - 1)][x] = b'*';
        }
        let mut out = String::new();
        for (row_i, row) in grid.iter().enumerate() {
            let label = if row_i == 0 {
                format!("{hi:8.3} |")
            } else if row_i == height - 1 {
                format!("{lo:8.3} |")
            } else {
                "         |".to_string()
            };
            out.push_str(&label);
            out.push_str(std::str::from_utf8(row).unwrap());
            out.push('\n');
        }
        out.push_str(&format!(
            "          +{}\n           steps 1..{}\n",
            "-".repeat(width),
            self.records.last().unwrap().step
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log(n: u64) -> RunLog {
        let mut log = RunLog::new();
        for s in 1..=n {
            log.push(StepRecord {
                step: s,
                loss: 5.0 / (s as f64).sqrt(),
                lr: 1e-3,
                seconds: if s == 1 { 10.0 } else { 1.0 },
                tokens_per_s: 1000.0,
            });
        }
        log
    }

    #[test]
    fn smoothing_and_means() {
        let log = sample_log(100);
        let s = log.smoothed_loss(10).unwrap();
        assert!(s < 1.0);
        // warmup step excluded from timing
        let t = log.mean_step_seconds(50).unwrap();
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn csv_roundtrip_lines() {
        let log = sample_log(5);
        let path = std::env::temp_dir().join("scalestudy_log_test.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 6);
        assert!(text.starts_with("step,loss"));
    }

    #[test]
    fn json_has_records_and_meta() {
        let mut log = sample_log(3);
        log.meta("preset", "tiny");
        let j = log.to_json();
        assert_eq!(j.path(&["meta", "preset"]).as_str(), Some("tiny"));
        assert_eq!(j.get("records").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn ascii_curve_renders() {
        let log = sample_log(50);
        let art = log.ascii_loss_curve(40, 8);
        assert!(art.contains('*'));
        assert!(art.lines().count() >= 8);
    }

    #[test]
    fn empty_log_is_safe() {
        let log = RunLog::new();
        assert!(log.last_loss().is_none());
        assert!(log.smoothed_loss(5).is_none());
        assert!(log.mean_step_seconds(5).is_none());
        assert_eq!(log.ascii_loss_curve(10, 5), "");
    }
}
