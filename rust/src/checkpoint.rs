//! Checkpointing: save/resume training state.
//!
//! Long pre-training runs on shared clusters (the paper's setting:
//! 205 trials queued on an 8-node pod) are checkpoint-driven; a trial
//! template is useless if the run cannot resume after preemption.  This
//! module persists the trainer's full state — flat parameters, sharded
//! optimizer state, step counter, config fingerprint — as a directory of
//! **NumPy `.npy` v1.0 files** plus a JSON meta file, so checkpoints are
//! directly inspectable from the python side (`numpy.load`) for debugging
//! parity.
//!
//! Layout:
//!   <dir>/meta.json            step, seed, ranks, zero_stage, fingerprint
//!   <dir>/params.npy           f32[flat_len]
//!   <dir>/rank<k>_m.npy        f32 optimizer first-moment shard
//!   <dir>/rank<k>_v.npy        f32 second-moment shard (AdamW only)

use crate::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Write a little-endian f32 1-D array as NumPy `.npy` v1.0.
pub fn write_npy_f32(path: &Path, data: &[f32]) -> Result<()> {
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({},), }}",
        data.len()
    );
    // pad with spaces so magic+header is a multiple of 64, ending in \n
    let base = 10 + header.len() + 1;
    let pad = (64 - base % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(b"\x93NUMPY\x01\x00")?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    // bulk little-endian write
    let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Read a `.npy` v1.0/v2.0 file containing a little-endian f32 1-D array.
pub fn read_npy_f32(path: &Path) -> Result<Vec<f32>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic[..6] != b"\x93NUMPY" {
        bail!("{}: not a .npy file", path.display());
    }
    let major = magic[6];
    let header_len = if major >= 2 {
        let mut b = [0u8; 4];
        f.read_exact(&mut b)?;
        u32::from_le_bytes(b) as usize
    } else {
        let mut b = [0u8; 2];
        f.read_exact(&mut b)?;
        u16::from_le_bytes(b) as usize
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);
    if !header.contains("'<f4'") {
        bail!("{}: expected '<f4' dtype, got header {header}", path.display());
    }
    if !header.contains("'fortran_order': False") {
        bail!("{}: fortran order unsupported", path.display());
    }
    // parse shape: (N,) — find the parenthesized part after 'shape':
    let shape_part = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .ok_or_else(|| anyhow!("{}: malformed shape", path.display()))?;
    let dims: Vec<usize> = shape_part
        .split(',')
        .filter_map(|p| p.trim().parse().ok())
        .collect();
    let n: usize = dims.iter().product::<usize>().max(if dims.is_empty() { 1 } else { 0 });
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if bytes.len() < n * 4 {
        bail!("{}: expected {} bytes of data, found {}", path.display(), n * 4, bytes.len());
    }
    Ok(bytes[..n * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Serialized training state (decoupled from `Trainer` so the runtime
/// and tools can load checkpoints without a PJRT client).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    pub step: u64,
    pub seed: u64,
    pub ranks: usize,
    pub zero_stage: usize,
    /// Identifies the artifact this state belongs to.
    pub preset: String,
    pub params: Vec<f32>,
    /// Per-rank (m, v) optimizer shards; `v` empty for SGD.
    pub opt_shards: Vec<(Vec<f32>, Vec<f32>)>,
}

impl TrainState {
    /// Staging directory used to make [`TrainState::save`] atomic:
    /// `<dir>.saving` next to the target, renamed into place once every
    /// file has been written.  A crash mid-save leaves either the previous
    /// complete checkpoint at `<dir>` or no checkpoint — never a torn one.
    fn staging_dir(dir: &Path) -> std::path::PathBuf {
        let name = dir
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "ckpt".to_string());
        dir.with_file_name(format!("{name}.saving"))
    }

    /// Save to a directory (created if needed).
    ///
    /// The write is atomic at the directory level: all files land in a
    /// `<dir>.saving` staging directory first, which then replaces `<dir>`
    /// via rename.  Readers never observe a partially written checkpoint,
    /// and a stale staging dir from an earlier crash is discarded.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let staging = Self::staging_dir(dir);
        // Discard leftovers from an interrupted earlier save.
        if staging.exists() {
            std::fs::remove_dir_all(&staging)
                .with_context(|| format!("clearing stale staging dir {}", staging.display()))?;
        }
        std::fs::create_dir_all(&staging)?;
        let meta = Json::obj(vec![
            ("step", Json::Num(self.step as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("ranks", Json::Num(self.ranks as f64)),
            ("zero_stage", Json::Num(self.zero_stage as f64)),
            ("preset", Json::Str(self.preset.clone())),
            ("flat_len", Json::Num(self.params.len() as f64)),
        ]);
        std::fs::write(staging.join("meta.json"), meta.pretty())?;
        write_npy_f32(&staging.join("params.npy"), &self.params)?;
        for (k, (m, v)) in self.opt_shards.iter().enumerate() {
            write_npy_f32(&staging.join(format!("rank{k}_m.npy")), m)?;
            if !v.is_empty() {
                write_npy_f32(&staging.join(format!("rank{k}_v.npy")), v)?;
            }
        }
        // Swap into place: drop the old checkpoint (complete by induction),
        // then rename the fully written staging dir onto the target path.
        if dir.exists() {
            std::fs::remove_dir_all(dir)
                .with_context(|| format!("removing old checkpoint {}", dir.display()))?;
        }
        std::fs::rename(&staging, dir).with_context(|| {
            format!("renaming {} -> {}", staging.display(), dir.display())
        })?;
        Ok(())
    }

    /// Load from a directory.
    pub fn load(dir: &Path) -> Result<TrainState> {
        let meta = Json::parse_file(&dir.join("meta.json"))?;
        let ranks = meta.get("ranks").as_usize().ok_or_else(|| anyhow!("meta missing ranks"))?;
        let params = read_npy_f32(&dir.join("params.npy"))?;
        let flat_len = meta.get("flat_len").as_usize().unwrap_or(params.len());
        if params.len() != flat_len {
            bail!("params.npy length {} != meta flat_len {flat_len}", params.len());
        }
        let mut opt_shards = Vec::with_capacity(ranks);
        for k in 0..ranks {
            let m = read_npy_f32(&dir.join(format!("rank{k}_m.npy")))?;
            let v_path = dir.join(format!("rank{k}_v.npy"));
            let v = if v_path.exists() { read_npy_f32(&v_path)? } else { Vec::new() };
            opt_shards.push((m, v));
        }
        Ok(TrainState {
            step: meta.get("step").as_usize().unwrap_or(0) as u64,
            seed: meta.get("seed").as_usize().unwrap_or(0) as u64,
            ranks,
            zero_stage: meta.get("zero_stage").as_usize().unwrap_or(1),
            preset: meta.get("preset").as_str().unwrap_or("").to_string(),
            params,
            opt_shards,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("scalestudy_ckpt_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn npy_roundtrip() {
        let dir = tmp("npy");
        std::fs::create_dir_all(&dir).unwrap();
        let data: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let p = dir.join("x.npy");
        write_npy_f32(&p, &data).unwrap();
        let back = read_npy_f32(&p).unwrap();
        assert_eq!(data, back);
    }

    #[test]
    fn npy_header_is_64_aligned() {
        let dir = tmp("npy_align");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.npy");
        write_npy_f32(&p, &[1.0, 2.0]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // data must start at a multiple of 64
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
        // numpy magic
        assert_eq!(&bytes[..6], b"\x93NUMPY");
    }

    #[test]
    fn npy_rejects_garbage() {
        let dir = tmp("npy_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.npy");
        std::fs::write(&p, b"not numpy at all").unwrap();
        assert!(read_npy_f32(&p).is_err());
    }

    #[test]
    fn empty_array_roundtrip() {
        let dir = tmp("npy_empty");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("e.npy");
        write_npy_f32(&p, &[]).unwrap();
        assert_eq!(read_npy_f32(&p).unwrap(), Vec::<f32>::new());
    }

    #[test]
    fn train_state_roundtrip() {
        let dir = tmp("state");
        let state = TrainState {
            step: 123,
            seed: 42,
            ranks: 3,
            zero_stage: 1,
            preset: "micro".into(),
            params: (0..517).map(|i| i as f32 * 0.5).collect(),
            opt_shards: vec![
                ((0..173).map(|i| i as f32).collect(), (0..173).map(|i| -(i as f32)).collect()),
                ((0..172).map(|i| i as f32 + 0.5).collect(), vec![0.0; 172]),
                ((0..172).map(|_| 1.0).collect(), vec![2.0; 172]),
            ],
        };
        state.save(&dir).unwrap();
        let back = TrainState::load(&dir).unwrap();
        assert_eq!(state, back);
    }

    #[test]
    fn sgd_state_without_v_roundtrips() {
        let dir = tmp("state_sgd");
        let state = TrainState {
            step: 1,
            seed: 2,
            ranks: 2,
            zero_stage: 1,
            preset: "t".into(),
            params: vec![1.0; 10],
            opt_shards: vec![(vec![0.5; 5], vec![]), (vec![0.25; 5], vec![])],
        };
        state.save(&dir).unwrap();
        let back = TrainState::load(&dir).unwrap();
        assert_eq!(state, back);
        assert!(back.opt_shards[0].1.is_empty());
    }

    #[test]
    fn corrupted_meta_fails_cleanly() {
        let dir = tmp("state_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), "{broken").unwrap();
        assert!(TrainState::load(&dir).is_err());
    }

    fn small_state(step: u64) -> TrainState {
        TrainState {
            step,
            seed: 7,
            ranks: 2,
            zero_stage: 1,
            preset: "micro".into(),
            params: (0..64).map(|i| i as f32 + step as f32).collect(),
            opt_shards: vec![
                (vec![0.5; 32], vec![1.5; 32]),
                (vec![0.25; 32], vec![2.5; 32]),
            ],
        }
    }

    #[test]
    fn save_is_atomic_no_staging_left_behind() {
        let dir = tmp("state_atomic");
        let state = small_state(10);
        state.save(&dir).unwrap();
        // The staging dir must be gone and the final dir complete.
        let staging = dir.with_file_name(format!(
            "{}.saving",
            dir.file_name().unwrap().to_string_lossy()
        ));
        assert!(!staging.exists(), "staging dir left behind");
        assert_eq!(TrainState::load(&dir).unwrap(), state);
    }

    #[test]
    fn save_replaces_previous_checkpoint() {
        let dir = tmp("state_replace");
        small_state(1).save(&dir).unwrap();
        let newer = small_state(2);
        newer.save(&dir).unwrap();
        let back = TrainState::load(&dir).unwrap();
        assert_eq!(back.step, 2);
        assert_eq!(back, newer);
    }

    #[test]
    fn save_recovers_from_stale_staging_dir() {
        let dir = tmp("state_stale");
        // Simulate a crash mid-save: a staging dir with garbage inside.
        let staging = dir.with_file_name(format!(
            "{}.saving",
            dir.file_name().unwrap().to_string_lossy()
        ));
        std::fs::create_dir_all(&staging).unwrap();
        std::fs::write(staging.join("meta.json"), "torn write ???").unwrap();
        std::fs::write(staging.join("params.npy"), b"\x93NUMPY garbage").unwrap();

        let state = small_state(3);
        state.save(&dir).unwrap();
        assert!(!staging.exists(), "stale staging dir not cleaned up");
        assert_eq!(TrainState::load(&dir).unwrap(), state);
    }

    #[test]
    fn torn_params_write_is_detected_on_load() {
        let dir = tmp("state_torn");
        let state = small_state(4);
        state.save(&dir).unwrap();
        // Truncate params.npy mid-data, as a torn write would.
        let p = dir.join("params.npy");
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 17]).unwrap();
        let err = TrainState::load(&dir).unwrap_err().to_string();
        assert!(err.contains("expected"), "unexpected error: {err}");
    }

    #[test]
    fn load_falls_back_to_previous_complete_checkpoint_past_stale_staging() {
        let dir = tmp("state_stale_load");
        // A complete checkpoint exists; a LATER save then crashed midway,
        // leaving a torn `.saving` staging dir beside it.  Restore must
        // read the previous complete checkpoint and never look at the
        // staging leftovers.
        let state = small_state(6);
        state.save(&dir).unwrap();
        let staging = dir.with_file_name(format!(
            "{}.saving",
            dir.file_name().unwrap().to_string_lossy()
        ));
        std::fs::create_dir_all(&staging).unwrap();
        std::fs::write(staging.join("meta.json"), "{\"step\": 999").unwrap();
        std::fs::write(staging.join("params.npy"), b"\x93NUMPY torn").unwrap();

        let back = TrainState::load(&dir).unwrap();
        assert_eq!(back, state, "restore must serve the last complete checkpoint");
        assert_eq!(back.step, 6, "the torn in-flight step must not surface");
        // ...and the next successful save discards the stale staging dir.
        small_state(7).save(&dir).unwrap();
        assert!(!staging.exists());
        assert_eq!(TrainState::load(&dir).unwrap().step, 7);
    }

    #[test]
    fn save_to_unwritable_target_errors_instead_of_panicking() {
        // The checkpoint target's parent is a regular FILE — every write
        // into it must fail.  `save` has to surface a structured error
        // (the trainer decides whether to retry or keep going), never
        // panic or leave a half-written directory behind.
        let base = tmp("state_unwritable");
        std::fs::write(&base, b"i am a file, not a directory").unwrap();
        let dir = base.join("ckpt");
        let err = small_state(8).save(&dir);
        assert!(err.is_err(), "save into an unwritable target must error");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(!msg.is_empty());
        // the target itself must not have appeared
        assert!(!dir.exists());
        let _ = std::fs::remove_file(&base);
    }

    #[test]
    fn short_params_array_fails_flat_len_check() {
        let dir = tmp("state_shortlen");
        let state = small_state(5);
        state.save(&dir).unwrap();
        // Replace params.npy with a valid but shorter array: the meta
        // flat_len cross-check must reject it.
        write_npy_f32(&dir.join("params.npy"), &[1.0, 2.0, 3.0]).unwrap();
        let err = TrainState::load(&dir).unwrap_err().to_string();
        assert!(err.contains("flat_len"), "unexpected error: {err}");
    }
}
