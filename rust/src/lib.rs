//! # ScaleStudy
//!
//! Reproduction of *"Scaling Studies for Efficient Parameter Search and
//! Parallelism for Large Language Model Pre-training"* (CS.DC 2023).
//!
//! The library has three strata (see `DESIGN.md`):
//!
//! 1. **Substrates** (no external deps beyond the offline vendor set):
//!    [`util`] (PRNG/stats), [`json`], [`configtoml`], [`cli`],
//!    [`benchkit`] (criterion-like harness), [`testkit`] (proptest-mini).
//! 2. **Study machinery** — analytical models of the paper's testbed:
//!    [`model`] (mt5 zoo + FLOP/memory accounting), [`hardware`]
//!    (A100/DGX cluster specs), [`comm`] (α–β collective cost models),
//!    [`zero`] (ZeRO stage 0–3 memory/comm), [`parallel`] (TP/PP),
//!    [`timeline`] (event-driven pipeline engine),
//!    [`sim`] (step-time simulator), [`convergence`] (loss scaling laws),
//!    [`hpo`] (funneled prune-and-combine search), [`sweep`] (parallel
//!    trial executor + memo cache), [`planner`] (auto-parallelism search),
//!    [`plancache`] (persistent cross-query plan-result cache),
//!    [`objective`] (pluggable plan ranking + compute-optimal
//!    plan-to-target), [`resilience`] (failure-aware goodput + what-if
//!    sweeps), [`server`] (planner-as-a-service query front-end),
//!    [`metrics`].
//! 3. **Real runtime** — the three-layer execution path: [`runtime`]
//!    (PJRT artifact loading/execution), [`data`] (synthetic corpus +
//!    parallel dataloader), [`train`] (multi-worker data-parallel trainer
//!    with ZeRO-style sharded optimizer states).

pub mod benchkit;
pub mod checkpoint;
pub mod cli;
pub mod comm;
pub mod configtoml;
pub mod convergence;
pub mod data;
pub mod hardware;
pub mod hpo;
pub mod json;
pub mod metrics;
pub mod model;
pub mod objective;
pub mod parallel;
pub mod plancache;
pub mod planner;
pub mod resilience;
pub mod runconfig;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod survival;
pub mod sweep;
pub mod testkit;
pub mod timeline;
pub mod train;
pub mod util;
pub mod xla;
pub mod zero;

/// Crate version (from Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Root of the artifacts directory, overridable with `SCALESTUDY_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("SCALESTUDY_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
