//! Data pipeline: synthetic pre-training corpus, tokenization into
//! seq2seq batches, and a multi-worker prefetching dataloader.
//!
//! The paper's corpora are not available (repro gate); the substitution is
//! a *learnable* synthetic seq2seq task with natural-language-like token
//! statistics: source sequences are drawn from a Zipfian unigram model
//! with first-order Markov structure, and the target is the source passed
//! through a fixed random vocabulary permutation ("translation") — the
//! model must learn cross-attention copying plus the permutation, so real
//! optimization progress is observable (loss curves in EXPERIMENTS.md E6).
//!
//! The dataloader is the paper's suspected scaling bottleneck: this module
//! implements both the serial loader and an N-worker prefetch loader over
//! a bounded channel (backpressure), and the `dataloader` bench (E4)
//! measures the throughput cliff the paper hypothesizes.

use crate::runtime::Batch;
use crate::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;
/// First "content" token id (0 = pad, 1 = bos).
pub const FIRST_CONTENT_ID: i32 = 2;

/// Geometry + task parameters of the synthetic corpus.
#[derive(Clone, Debug)]
pub struct CorpusCfg {
    pub vocab: usize,
    pub batch_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
    /// Zipf exponent of the unigram distribution (~1.1 for natural text).
    pub zipf_s: f64,
    /// Probability of continuing a Markov bigram run instead of
    /// resampling from the unigram model.
    pub markov_p: f64,
    /// Fraction of samples whose tail is padding (variable lengths).
    pub pad_frac: f64,
    /// Per-sample CPU cost knob: extra synthesis work per token to mimic
    /// real tokenization/IO cost in the dataloader benches (0 = free).
    pub work_per_token: usize,
}

impl CorpusCfg {
    /// Config matched to a runtime manifest.
    pub fn for_manifest(m: &crate::runtime::Manifest) -> CorpusCfg {
        CorpusCfg {
            vocab: m.vocab,
            batch_size: m.batch_size,
            enc_len: m.enc_len,
            dec_len: m.dec_len,
            zipf_s: 1.1,
            markov_p: 0.35,
            pad_frac: 0.2,
            work_per_token: 0,
        }
    }
}

/// The synthetic task: Zipf+Markov source, permuted copy target.
#[derive(Clone)]
pub struct TaskGen {
    cfg: CorpusCfg,
    /// Fixed vocabulary permutation the model must learn.
    perm: Arc<Vec<i32>>,
}

impl TaskGen {
    pub fn new(cfg: CorpusCfg, task_seed: u64) -> TaskGen {
        let mut rng = Rng::new(task_seed ^ 0x7A5C_1234_DEAD_BEEF);
        let content = (cfg.vocab as i32) - FIRST_CONTENT_ID;
        let mut perm: Vec<i32> = (0..content).collect();
        rng.shuffle(&mut perm);
        TaskGen { cfg, perm: Arc::new(perm) }
    }

    fn map_token(&self, t: i32) -> i32 {
        debug_assert!(t >= FIRST_CONTENT_ID);
        FIRST_CONTENT_ID + self.perm[(t - FIRST_CONTENT_ID) as usize]
    }

    /// Generate one batch with the given stream RNG.
    pub fn batch(&self, rng: &mut Rng) -> Batch {
        let c = &self.cfg;
        let content = (c.vocab as u64) - FIRST_CONTENT_ID as u64;
        let mut enc = vec![PAD_ID; c.batch_size * c.enc_len];
        let mut dec_in = vec![PAD_ID; c.batch_size * c.dec_len];
        let mut targets = vec![PAD_ID; c.batch_size * c.dec_len];
        for b in 0..c.batch_size {
            // variable source length
            let len = if rng.chance(c.pad_frac) {
                (c.enc_len / 2) + rng.index(c.enc_len / 2)
            } else {
                c.enc_len
            };
            let mut prev: i32 = FIRST_CONTENT_ID + rng.zipf(content, c.zipf_s) as i32 - 1;
            for i in 0..len {
                let tok = if i > 0 && rng.chance(c.markov_p) {
                    // bigram continuation: deterministic successor
                    FIRST_CONTENT_ID
                        + ((prev - FIRST_CONTENT_ID + 7) % content as i32)
                } else {
                    FIRST_CONTENT_ID + rng.zipf(content, c.zipf_s) as i32 - 1
                };
                enc[b * c.enc_len + i] = tok;
                prev = tok;
                // optional synthetic CPU cost (tokenizer/IO stand-in)
                for w in 0..c.work_per_token {
                    std::hint::black_box(w * 2654435761);
                }
            }
            // target: permuted copy of the source prefix
            let tlen = c.dec_len.min(len);
            dec_in[b * c.dec_len] = BOS_ID;
            for i in 0..tlen {
                let mapped = self.map_token(enc[b * c.enc_len + i]);
                targets[b * c.dec_len + i] = mapped;
                if i + 1 < c.dec_len {
                    dec_in[b * c.dec_len + i + 1] = mapped;
                }
            }
        }
        Batch { enc, dec_in, targets }
    }
}

/// Shared throughput counters for a loader.
#[derive(Default)]
pub struct LoaderStats {
    pub batches: AtomicU64,
    pub wait_ns: AtomicU64,
}

/// A source of batches: serial (generated inline on `next()`) or
/// multi-worker (N producer threads + bounded prefetch queue).
pub enum Loader {
    Serial { task: TaskGen, rng: Rng, stats: Arc<LoaderStats> },
    Workers {
        rx: Receiver<Batch>,
        handles: Vec<JoinHandle<()>>,
        stats: Arc<LoaderStats>,
    },
}

impl Loader {
    /// The serial loader the paper suspects: every batch is synthesized on
    /// the training thread.
    pub fn serial(task: TaskGen, seed: u64) -> Loader {
        Loader::Serial { task, rng: Rng::new(seed), stats: Arc::new(LoaderStats::default()) }
    }

    /// N worker threads prefetching into a bounded queue of `depth`.
    /// Each worker draws from an independent split of `seed`, so the
    /// stream is deterministic *as a set* (arrival order may vary).
    pub fn workers(task: TaskGen, seed: u64, n_workers: usize, depth: usize) -> Loader {
        assert!(n_workers >= 1);
        let (tx, rx) = sync_channel(depth.max(1));
        let stats = Arc::new(LoaderStats::default());
        let handles = (0..n_workers)
            .map(|w| {
                let tx = tx.clone();
                let task = task.clone();
                let mut rng = Rng::new(seed).split(w as u64);
                std::thread::Builder::new()
                    .name(format!("loader-{w}"))
                    .spawn(move || {
                        loop {
                            let b = task.batch(&mut rng);
                            if tx.send(b).is_err() {
                                return; // consumer dropped
                            }
                        }
                    })
                    .expect("spawn loader worker")
            })
            .collect();
        Loader::Workers { rx, handles, stats }
    }

    /// Next batch (blocking).
    pub fn next(&mut self) -> Batch {
        let t0 = std::time::Instant::now();
        let (batch, stats) = match self {
            Loader::Serial { task, rng, stats } => (task.batch(rng), stats.clone()),
            Loader::Workers { rx, stats, .. } => {
                (rx.recv().expect("loader workers died"), stats.clone())
            }
        };
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.wait_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        batch
    }

    pub fn stats(&self) -> Arc<LoaderStats> {
        match self {
            Loader::Serial { stats, .. } => stats.clone(),
            Loader::Workers { stats, .. } => stats.clone(),
        }
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        if let Loader::Workers { rx, handles, .. } = self {
            // drain so senders unblock, then let threads see the closed
            // channel and exit
            while rx.try_recv().is_ok() {}
            // receiver is dropped with self; workers exit on send error
            for h in handles.drain(..) {
                // detach: the thread exits on its next send attempt
                drop(h);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CorpusCfg {
        CorpusCfg {
            vocab: 512,
            batch_size: 4,
            enc_len: 32,
            dec_len: 32,
            zipf_s: 1.1,
            markov_p: 0.35,
            pad_frac: 0.3,
            work_per_token: 0,
        }
    }

    #[test]
    fn batches_well_formed() {
        let task = TaskGen::new(cfg(), 1);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let b = task.batch(&mut rng);
            assert_eq!(b.enc.len(), 4 * 32);
            assert_eq!(b.dec_in.len(), 4 * 32);
            assert_eq!(b.targets.len(), 4 * 32);
            for &t in b.enc.iter().chain(&b.dec_in).chain(&b.targets) {
                assert!((0..512).contains(&t), "token {t} out of range");
            }
            // decoder input starts with BOS in every row
            for row in 0..4 {
                assert_eq!(b.dec_in[row * 32], BOS_ID);
            }
        }
    }

    #[test]
    fn target_is_permuted_copy() {
        let task = TaskGen::new(cfg(), 1);
        let mut rng = Rng::new(3);
        let b = task.batch(&mut rng);
        // for non-pad positions, target = perm(enc) and dec_in is the
        // target shifted right
        for row in 0..4 {
            for i in 0..31 {
                let tgt = b.targets[row * 32 + i];
                if tgt == PAD_ID {
                    continue;
                }
                assert_eq!(tgt, task.map_token(b.enc[row * 32 + i]));
                assert_eq!(b.dec_in[row * 32 + i + 1], tgt);
            }
        }
    }

    #[test]
    fn permutation_is_bijective() {
        let task = TaskGen::new(cfg(), 9);
        let mut seen = std::collections::HashSet::new();
        for t in FIRST_CONTENT_ID..512 {
            assert!(seen.insert(task.map_token(t)));
        }
    }

    #[test]
    fn serial_loader_deterministic() {
        let task = TaskGen::new(cfg(), 1);
        let mut a = Loader::serial(task.clone(), 42);
        let mut b = Loader::serial(task, 42);
        for _ in 0..5 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn worker_loader_produces_and_stops() {
        let task = TaskGen::new(cfg(), 1);
        let mut l = Loader::workers(task, 7, 2, 4);
        for _ in 0..10 {
            let b = l.next();
            assert_eq!(b.enc.len(), 4 * 32);
        }
        assert_eq!(l.stats().batches.load(Ordering::Relaxed), 10);
        drop(l); // must not hang
    }

    #[test]
    fn zipf_statistics_present() {
        // frequent tokens should dominate: count token frequencies over
        // many batches and check head-heaviness
        let task = TaskGen::new(cfg(), 1);
        let mut rng = Rng::new(5);
        let mut counts = vec![0u32; 512];
        for _ in 0..50 {
            let b = task.batch(&mut rng);
            for &t in &b.enc {
                if t >= FIRST_CONTENT_ID {
                    counts[t as usize] += 1;
                }
            }
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let total: u32 = sorted.iter().sum();
        let top10: u32 = sorted[..10].iter().sum();
        assert!(
            top10 as f64 > 0.2 * total as f64,
            "top-10 tokens should carry >20% of mass, got {}",
            top10 as f64 / total as f64
        );
    }
}
