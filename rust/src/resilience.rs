//! Failure-aware goodput planning and the resilience what-if engine.
//!
//! Everything the planner prices elsewhere assumes a perfect, failure-free
//! cluster.  This module turns per-step time into **expected goodput under
//! failures** — the regime the paper actually ran in (205 queued trials on
//! a shared 8-node pod, where preemptions and degraded interconnects
//! decide real throughput; fault tolerance and elasticity are first-class
//! chapters of Duan et al. 2024, arXiv 2407.20018).
//!
//! ## The failure model
//!
//! Per-node failures are Poisson with mean time between failures
//! [`FailureModel::mtbf_hours`]; a plan running on `n` nodes fails at the
//! cluster rate λ = n / MTBF — the *blast radius* term that lets a slower
//! 4-node plan beat a faster 8-node plan once failures are priced.
//! On top of the independent per-node process, a cluster may declare
//! **correlated blast-domain levels** ([`crate::hardware::BlastDomain`]
//! on [`ClusterSpec::domains`]): every `size` consecutive nodes share a
//! switch / PSU / rack that fails as its own Poisson process and takes
//! out all of them at once.  A plan on `n` nodes then adds
//! `ceil(n / size) / MTBF_level` per level to λ
//! ([`FailureModel::lambda_for`]) — the rate climbs in coarse steps at
//! domain boundaries, so wide plans are punished super-linearly relative
//! to the independent model.  An empty `domains` list (the default
//! everywhere) routes through the exact PR 7 expressions, bit for bit.
//! Checkpoint write/restore cost derives from the **same ZeRO state-bytes
//! expression the memory model prices** ([`crate::zero::checkpoint_bytes`]
//! via [`crate::sim::checkpoint_state_bytes`]): fp16 parameters + the fp32
//! optimizer master state, (2 + K)·Ψ bytes, streamed at
//! `min(shared_bw, nodes · per_node_bw)` (ZeRO-sharded writers scale with
//! the pod until the shared storage front-end binds).
//!
//! ## Checkpoint policies
//!
//! [`CheckpointPolicy`] decides what part of a checkpoint lands on the
//! step's critical path.  `Sync` is the PR 7 model: the full write
//! blocks training.  `Async` stalls only for the in-HBM snapshot and
//! drains the persist against compute; `Tiered` snapshots to node-local
//! NVMe (optionally replicating to a buddy node) and drains to the
//! shared tier, with restore preferring the nearest surviving tier
//! (rate-weighted over the failure topology).  Drained I/O is absorbed
//! at [`crate::timeline::checkpoint_drain_budget`] seconds per step —
//! the timeline engine's fluid comm-stream overlap budget applied to the
//! backward-pass share of a step — and only the spill past the budget
//! is charged.  This moves the Young/Daly optimum (δ_eff ≪ δ_full): the
//! interval optimizer becomes piecewise
//! ([`optimal_interval_steps_policy`]) and is re-proved against brute
//! force under every policy.
//!
//! The checkpoint interval is chosen Young/Daly-style: the period
//! minimizing expected wall time per useful step has the closed form
//! `W* = δ + √(δ² + 2δ(1 + λR)/λ)` — Young's τ* = √(2δ/λ) in the
//! rare-failure limit — and an exact integer scan around it settles
//! integrality ([`optimal_interval_steps`], property-tested against
//! brute force).
//! With interval `m` steps of `s` seconds and checkpoint write cost δ,
//! one period is `W = m·s + δ` wall seconds; first-order in λ the
//! expected wall time per period is `W · (1 + λ·(W/2 + R))` — λW failures
//! each losing W/2 of rework plus a restore+restart cost R — so
//!
//! ```text
//! effective seconds/useful step = W · (1 + λ·(W/2 + R)) / m
//! goodput fraction              = s / effective
//! ```
//!
//! monotone in λ, never zero, and exactly `s` at λ = 0.
//!
//! ## Failure-aware planning ([`plan_resilient`])
//!
//! Goodput is NOT a monotone transform of step time across the whole
//! space: δ depends on the optimizer (K bytes/param) and λ on the node
//! count.  But a planner *branch* fixes both, and within a branch
//! `effective(s)` is strictly increasing in `s` — exactly the contract
//! of a planner [`crate::objective::Objective`].  `plan_resilient` is
//! therefore a thin wrapper over
//! [`crate::planner::plan_with`]`(…, Objective::Goodput)`: one
//! branch-and-bound pass whose pruning, selection and frontier all rank
//! by expected seconds per useful step.  (An earlier version re-ranked
//! per-(node count, optimizer) slice bests by hand — that decomposition
//! survives as the reference oracle the property suite checks the
//! single-pass search against, via [`crate::planner::PlanSpace::slice`].)
//! With the failure model disabled the result embeds a plain
//! [`crate::planner::plan`] run, bit-identical to the failure-free path
//! by construction.
//!
//! ## What-if sweeps
//!
//! [`whatif_sweep`] replans under derated NIC/NVLink rates, seeded
//! per-micro-batch compute jitter (measured p99 step time through the
//! timeline engine; the whole-node straggler reshaping survives as
//! [`jitter_cluster`]), or a ladder of per-node or blast-domain MTBFs,
//! and
//! [`phase_boundaries`] reports where the winning plan *flips* — the
//! phase structure of plan space that LLMSFTComBenchmarking measures
//! empirically.  [`replan_after_failure`] prices elastic recovery: drop
//! `k` nodes, replan on the survivor cluster, and price the restart from
//! the last checkpoint.

use crate::hardware::{BlastDomain, ClusterSpec, NodeGroup};
use crate::model::ModelCfg;
use crate::objective::Objective;
use crate::plancache::PlanCache;
use crate::planner::{self, PlanPoint, PlanResult, PlanSeed, PlanSpace};
use crate::sim::{self, TrainSetup, Workload};
use crate::sweep::{SimCache, Sweep};

/// Seconds per hour (the MTBF knob is in hours; the model runs in seconds).
const HOUR_S: f64 = 3600.0;

/// Fixed seed and sample count for the measured-p99 jitter pricing in
/// [`whatif_sweep`] — module constants so the CLI and serve front-ends
/// stay byte-identical on the jitter axis.
const JITTER_SEED: u64 = 0x5CA1_AB1E;
const JITTER_SAMPLES: usize = 64;

/// Per-node failure statistics plus the checkpoint I/O path.
#[derive(Clone, Debug)]
pub struct FailureModel {
    /// Mean time between failures of ONE node, in hours.  `0` (or any
    /// non-finite / non-positive value) disables the failure model: every
    /// consumer degrades to the exact failure-free path.
    pub mtbf_hours: f64,
    /// Per-node checkpoint write bandwidth (bytes/s) — ZeRO-sharded
    /// writers, one per node, until the shared front-end binds.
    pub write_bw: f64,
    /// Per-node restore read bandwidth (bytes/s).
    pub read_bw: f64,
    /// Shared storage front-end ceiling (bytes/s) across all writers.
    pub shared_bw: f64,
    /// Fixed restart cost per failure (seconds): requeue, scheduler,
    /// process launch, NCCL re-init — everything that is not restore I/O.
    pub restart_overhead_s: f64,
    /// How checkpoints hit the critical path ([`CheckpointPolicy`]).
    /// `Sync` (the default) is the exact PR 7 blocking-write model.
    pub policy: CheckpointPolicy,
}

impl Default for FailureModel {
    fn default() -> FailureModel {
        FailureModel {
            mtbf_hours: 0.0, // disabled
            // a DGX node writes a sharded checkpoint at roughly NVMe/NFS
            // client speed; the shared front-end saturates around 10
            // concurrent writers (same shape as the storage model in
            // `ClusterSpec::lps_pod`)
            write_bw: 2e9,
            read_bw: 2e9,
            shared_bw: 20e9,
            restart_overhead_s: 180.0,
            policy: CheckpointPolicy::Sync,
        }
    }
}

/// What part of a checkpoint lands on the step's critical path.
#[derive(Clone, Debug, PartialEq)]
pub enum CheckpointPolicy {
    /// The PR 7 model: the full write blocks training
    /// (δ = bytes / min(nodes·write_bw, shared_bw) on the critical
    /// path), restore reads the shared tier.
    Sync,
    /// Snapshot-then-drain: training stalls only for the in-HBM/host
    /// snapshot, then the persist drains against compute inside the
    /// per-step overlap budget ([`crate::timeline::checkpoint_drain_budget`]);
    /// only drain spilling past the budget is charged.  Restore reads
    /// the shared tier like `Sync`.
    Async {
        /// Critical-path stall per checkpoint (seconds): the
        /// device-side snapshot of the (2 + K)·Ψ state.
        snapshot_s: f64,
        /// Per-node drain bandwidth to persistent storage (bytes/s),
        /// still capped by the model's shared front-end ceiling.
        drain_bw: f64,
    },
    /// Two-tier: snapshot to node-local NVMe at `local_bw` per node —
    /// the only critical-path stall, doubled when `replicate` also
    /// copies each shard to a buddy node — then drain to the shared
    /// tier at `shared_bw` aggregate.  Restore prefers the nearest
    /// surviving tier: with replication, node-level failures restore
    /// from the buddy's local shard and only domain-level failures fall
    /// back to the shared tier (expected restore is rate-weighted over
    /// the failure topology); without replication every restore reads
    /// the shared tier.
    Tiered {
        /// Per-node local-tier (NVMe) bandwidth, bytes/s.
        local_bw: f64,
        /// Shared-tier aggregate drain/read bandwidth, bytes/s.
        shared_bw: f64,
        /// Replicate each local shard to a buddy node.
        replicate: bool,
    },
}

impl Default for CheckpointPolicy {
    fn default() -> CheckpointPolicy {
        CheckpointPolicy::Sync
    }
}

impl FailureModel {
    /// An enabled model at `mtbf_hours` per node, default I/O path.
    pub fn with_mtbf(mtbf_hours: f64) -> FailureModel {
        FailureModel { mtbf_hours, ..FailureModel::default() }
    }

    /// A disabled model: every consumer takes the failure-free path.
    pub fn disabled() -> FailureModel {
        FailureModel::default()
    }

    pub fn enabled(&self) -> bool {
        self.mtbf_hours.is_finite() && self.mtbf_hours > 0.0
    }

    /// Cluster failure rate (failures/second) for a plan on `nodes`
    /// nodes: independent per-node Poisson processes superpose.
    pub fn lambda_per_s(&self, nodes: usize) -> f64 {
        if !self.enabled() {
            return 0.0;
        }
        nodes.max(1) as f64 / (self.mtbf_hours * HOUR_S)
    }

    /// Cluster interruption rate (failures/second) for a plan on
    /// `cluster`: the independent per-node term plus one Poisson term
    /// per enabled correlated blast-domain level
    /// ([`ClusterSpec::domains`]).  A plan spanning `n` nodes touches
    /// `ceil(n / size)` instances of each level, so the rate climbs in
    /// coarse steps at domain boundaries.  With no domains declared
    /// this is exactly [`FailureModel::lambda_per_s`], bit for bit.
    pub fn lambda_for(&self, cluster: &ClusterSpec) -> f64 {
        let n = cluster.total_nodes();
        let mut lambda = self.lambda_per_s(n);
        for d in &cluster.domains {
            if d.enabled() {
                let instances = (n.max(1) as f64 / d.size.max(1) as f64).ceil();
                lambda += instances / (d.mtbf_hours * HOUR_S);
            }
        }
        lambda
    }

    /// Does any failure source fire on `cluster` — the per-node process
    /// or at least one enabled blast-domain level?  (A domain-only
    /// model, `mtbf_hours = 0` with declared domains, still prices
    /// failures.)  With no domains declared this is exactly
    /// [`FailureModel::enabled`].
    pub fn enabled_for(&self, cluster: &ClusterSpec) -> bool {
        self.enabled() || cluster.domains.iter().any(|d| d.enabled())
    }

    /// The per-level failure decomposition the survival engine samples
    /// from: the node level (one instance per node) plus every enabled
    /// blast-domain level.  The level rates sum to
    /// [`FailureModel::lambda_for`] in the same order, bit for bit.
    pub fn topology(&self, cluster: &ClusterSpec) -> FailureTopology {
        let n = cluster.total_nodes();
        let mut levels = Vec::new();
        if self.enabled() {
            levels.push(FailureLevel {
                name: "node".into(),
                size: 1,
                mtbf_hours: self.mtbf_hours,
                instances: n.max(1),
                lambda_per_s: self.lambda_per_s(n),
            });
        }
        for d in &cluster.domains {
            if d.enabled() {
                let instances = (n.max(1) as f64 / d.size.max(1) as f64).ceil();
                levels.push(FailureLevel {
                    name: d.name.clone(),
                    size: d.size.max(1),
                    mtbf_hours: d.mtbf_hours,
                    instances: instances as usize,
                    lambda_per_s: instances / (d.mtbf_hours * HOUR_S),
                });
            }
        }
        FailureTopology { levels }
    }

    /// How the interruption rate splits between node-level failures
    /// (the failed node's local tier is lost but a replicated buddy
    /// shard survives) and domain-level failures (whole blast domains
    /// die — only the shared tier survives).  `(1.0, 0.0)` when nothing
    /// fails at all, so a disabled model still prices an optimistic
    /// local restore.
    fn failure_shares(&self, cluster: &ClusterSpec) -> (f64, f64) {
        let node = self.lambda_per_s(cluster.total_nodes());
        let total = self.lambda_for(cluster);
        if !(total > 0.0) {
            return (1.0, 0.0);
        }
        let node_share = node / total;
        (node_share, 1.0 - node_share)
    }

    /// Checkpoint cost for one setup under the model's
    /// [`CheckpointPolicy`].  Bytes come from the same ZeRO expression
    /// the memory model prices ([`sim::checkpoint_state_bytes`]);
    /// `write_s` is the critical-path stall, `drain_s` the overlappable
    /// persist I/O (zero for `Sync`), `restore_s` the expected restore
    /// read.  The `Sync` arm is the exact PR 7 expression.
    pub fn checkpoint_cost(&self, setup: &TrainSetup) -> CheckpointCost {
        let bytes = sim::checkpoint_state_bytes(setup);
        let nodes = setup.cluster.total_nodes().max(1) as f64;
        let per = |bw: f64| if bw > 0.0 { bytes / bw } else { f64::INFINITY };
        match &self.policy {
            CheckpointPolicy::Sync => {
                let write = (nodes * self.write_bw).min(self.shared_bw);
                let read = (nodes * self.read_bw).min(self.shared_bw);
                CheckpointCost { bytes, write_s: per(write), drain_s: 0.0, restore_s: per(read) }
            }
            CheckpointPolicy::Async { snapshot_s, drain_bw } => {
                let read = (nodes * self.read_bw).min(self.shared_bw);
                CheckpointCost {
                    bytes,
                    write_s: snapshot_s.max(0.0),
                    drain_s: per((nodes * drain_bw).min(self.shared_bw)),
                    restore_s: per(read),
                }
            }
            CheckpointPolicy::Tiered { local_bw, shared_bw, replicate } => {
                let copies = if *replicate { 2.0 } else { 1.0 };
                let local = per(nodes * local_bw);
                let shared = per(*shared_bw);
                let restore = if *replicate {
                    // nearest surviving tier, rate-weighted: a node
                    // failure leaves the buddy's local shard, a domain
                    // failure only the shared tier
                    let (node_share, domain_share) = self.failure_shares(&setup.cluster);
                    node_share * local + domain_share * shared
                } else {
                    shared
                };
                CheckpointCost { bytes, write_s: copies * local, drain_s: shared, restore_s: restore }
            }
        }
    }

    /// Expected goodput of a plan priced at `step_s` seconds/step.
    pub fn goodput(&self, setup: &TrainSetup, step_s: f64) -> Goodput {
        let ckpt = self.checkpoint_cost(setup);
        let lambda = self.lambda_for(&setup.cluster);
        if !self.enabled_for(&setup.cluster) || !(step_s.is_finite() && step_s > 0.0) {
            // exact failure-free degeneration: no checkpoints, no rework
            return Goodput {
                interval_steps: 0,
                checkpoint_write_s: ckpt.write_s,
                restore_s: ckpt.restore_s,
                lambda_per_s: lambda,
                effective_seconds_per_step: step_s,
                goodput_fraction: 1.0,
            };
        }
        let recovery = ckpt.restore_s + self.restart_overhead_s;
        let budget = crate::timeline::checkpoint_drain_budget(step_s);
        let m = optimal_interval_steps_policy(
            step_s, ckpt.write_s, ckpt.drain_s, budget, lambda, recovery,
        );
        let eff = effective_seconds_per_step_policy(
            m, step_s, ckpt.write_s, ckpt.drain_s, budget, lambda, recovery,
        );
        Goodput {
            interval_steps: m,
            checkpoint_write_s: ckpt.write_s,
            restore_s: ckpt.restore_s,
            lambda_per_s: lambda,
            effective_seconds_per_step: eff,
            goodput_fraction: step_s / eff,
        }
    }
}

/// The per-level failure decomposition of one (model, cluster) pair —
/// what [`crate::survival`] samples failure traces from.
#[derive(Clone, Debug)]
pub struct FailureTopology {
    pub levels: Vec<FailureLevel>,
}

impl FailureTopology {
    /// Total interruption rate across every level — equals
    /// [`FailureModel::lambda_for`] bit for bit (same summation order).
    pub fn total_lambda_per_s(&self) -> f64 {
        self.levels.iter().fold(0.0, |acc, l| acc + l.lambda_per_s)
    }
}

/// One level of the failure topology: `instances` independent Poisson
/// processes, each killing `size` nodes at once when it fires.
#[derive(Clone, Debug)]
pub struct FailureLevel {
    /// Level name ("node", "switch", "psu", "rack").
    pub name: String,
    /// Nodes lost per failure at this level.
    pub size: usize,
    /// MTBF of ONE instance, in hours.
    pub mtbf_hours: f64,
    /// Instances the plan spans (`ceil(nodes / size)`).
    pub instances: usize,
    /// Aggregate failure rate of the level (failures/second).
    pub lambda_per_s: f64,
}

/// Checkpoint I/O cost for one setup.
#[derive(Clone, Copy, Debug)]
pub struct CheckpointCost {
    /// Unique persisted bytes: (2 + K)·Ψ, fp16 params + fp32 opt state.
    pub bytes: f64,
    /// Critical-path stall per checkpoint (δ₀ in the interval model):
    /// the full write under `Sync`, only the snapshot otherwise.
    pub write_s: f64,
    /// Persist I/O that drains against compute (0 under `Sync`); the
    /// part exceeding the per-period overlap budget spills back onto
    /// the critical path.
    pub drain_s: f64,
    /// Expected seconds to read the checkpoint back on restart.
    pub restore_s: f64,
}

/// Expected-goodput breakdown for one plan under a [`FailureModel`].
#[derive(Clone, Copy, Debug)]
pub struct Goodput {
    /// Optimal checkpoint interval in steps (0 = failures disabled:
    /// never checkpoint).
    pub interval_steps: usize,
    pub checkpoint_write_s: f64,
    pub restore_s: f64,
    pub lambda_per_s: f64,
    /// Wall seconds per *useful* step once checkpoint overhead and
    /// expected rework are amortized in.
    pub effective_seconds_per_step: f64,
    /// `step_s / effective` — 1.0 when failures are disabled, strictly
    /// below 1.0 otherwise.
    pub goodput_fraction: f64,
}

/// Expected wall seconds per useful step at checkpoint interval `m`:
/// `W·(1 + λ·(W/2 + R)) / m` with `W = m·s + δ` (module docs derive it).
fn effective_seconds_per_step(m: usize, step_s: f64, delta: f64, lambda: f64, recovery: f64) -> f64 {
    let m = m.max(1);
    let w = m as f64 * step_s + delta;
    w * (1.0 + lambda * (w / 2.0 + recovery)) / m as f64
}

/// Optimal integer checkpoint interval (steps between checkpoints) for
/// step time `step_s`, checkpoint write cost `delta`, failure rate
/// `lambda` and per-failure recovery cost `recovery`.  The continuous
/// relaxation of [`effective_seconds_per_step`] in the period
/// `W = m·s + δ` is `s·W·(1 + λ(W/2 + R))/(W − δ)`, whose derivative
/// vanishes at the closed form `W* = δ + √(δ² + 2δ(1 + λR)/λ)` — in
/// the rare-failure limit this degenerates to Young's τ* = √(2δ/λ),
/// but unlike Young's seed it stays exact when λδ is large (frequent
/// failures against an expensive checkpoint).  The objective is
/// strictly unimodal in `m`, so the integer optimum sits adjacent to
/// the continuous one; a short scan around it (plus the boundary
/// `m = 1`) settles integrality — property-tested optimal against a
/// full brute-force sweep.
pub fn optimal_interval_steps(step_s: f64, delta: f64, lambda: f64, recovery: f64) -> usize {
    if !(lambda > 0.0) || !(step_s > 0.0) {
        return 1; // degenerate inputs: any interval is equivalent
    }
    if delta <= 0.0 {
        return 1; // free checkpoints: checkpoint every step
    }
    // m* = (W* − δ)/s; clamp before the cast (λ → 0⁺ sends it huge)
    let span = (delta * delta + 2.0 * delta * (1.0 + lambda * recovery) / lambda).sqrt();
    let seed = (span / step_s).round().clamp(1.0, 1e15) as usize;
    let lo = seed.saturating_sub(4).max(1);
    let hi = seed.saturating_add(4);
    let mut best = 1usize;
    let mut best_eff = effective_seconds_per_step(1, step_s, delta, lambda, recovery);
    for m in lo..=hi {
        let eff = effective_seconds_per_step(m, step_s, delta, lambda, recovery);
        if eff < best_eff {
            best_eff = eff;
            best = m;
        }
    }
    best
}

/// Expected wall seconds per useful step under a checkpoint policy with
/// a drained component: per period of `m` steps, training stalls for
/// `stall0` (the snapshot) while `drain_s` of persist I/O overlaps with
/// the following steps at `budget_per_step` seconds absorbed per step
/// ([`crate::timeline::checkpoint_drain_budget`]); only the spill past
/// `m · budget` lands on the critical path.  `drain_s <= 0` routes to
/// the synchronous expression unchanged — same arguments, same bits —
/// which is what keeps the `Sync` policy exactly PR 7.
pub fn effective_seconds_per_step_policy(
    m: usize,
    step_s: f64,
    stall0: f64,
    drain_s: f64,
    budget_per_step: f64,
    lambda: f64,
    recovery: f64,
) -> f64 {
    if drain_s <= 0.0 {
        return effective_seconds_per_step(m, step_s, stall0, lambda, recovery);
    }
    let m = m.max(1);
    let spill = (drain_s - m as f64 * budget_per_step).max(0.0);
    let delta = stall0 + spill;
    let w = m as f64 * step_s + delta;
    w * (1.0 + lambda * (w / 2.0 + recovery)) / m as f64
}

/// [`optimal_interval_steps`] generalized to checkpoint policies with a
/// drained component.  The objective is piecewise in `m`: below the
/// absorption threshold `m_th = ceil(drain_s / budget)` the effective
/// checkpoint cost is `δ(m) = stall0 + drain_s − m·budget` (the period
/// slope shrinks to `s − budget`), above it `δ(m) = stall0`.  Each
/// regime is the synchronous objective under a substitution, so each
/// has its own Young/Daly closed form and is strictly unimodal; the
/// discrete optimum sits adjacent to one of the two closed-form seeds
/// or the regime boundary.  A short scan over that candidate set (plus
/// the `m = 1` boundary) settles integrality — property-tested optimal
/// against a full brute-force sweep like the synchronous optimizer.
/// `drain_s <= 0` routes to [`optimal_interval_steps`] unchanged.
pub fn optimal_interval_steps_policy(
    step_s: f64,
    stall0: f64,
    drain_s: f64,
    budget_per_step: f64,
    lambda: f64,
    recovery: f64,
) -> usize {
    if drain_s <= 0.0 {
        return optimal_interval_steps(step_s, stall0, lambda, recovery);
    }
    if !(lambda > 0.0) || !(step_s > 0.0) {
        return 1; // degenerate inputs: any interval is equivalent
    }
    let mut cands: Vec<usize> = vec![1];
    // closed-form seed of the synchronous objective at effective cost
    // `delta` and per-step period slope `slope`:
    // W* = δ + √(δ² + 2δ(1 + λR)/λ), m* = (W* − δ)/slope
    let mut push_seed = |delta: f64, slope: f64| {
        if !(delta > 0.0) || !(slope > 0.0) {
            return; // free checkpoints / absorbed slope: boundary wins
        }
        let span = (delta * delta + 2.0 * delta * (1.0 + lambda * recovery) / lambda).sqrt();
        let seed = (span / slope).round().clamp(1.0, 1e15) as usize;
        for m in seed.saturating_sub(4).max(1)..=seed.saturating_add(4) {
            cands.push(m);
        }
    };
    // spill regime (m below the absorption threshold)
    push_seed(stall0 + drain_s, step_s - budget_per_step);
    // absorbed regime (the drain hides entirely)
    push_seed(stall0, step_s);
    // the regime boundary itself
    if budget_per_step > 0.0 {
        let m_th = (drain_s / budget_per_step).ceil().clamp(1.0, 1e15) as usize;
        for m in m_th.saturating_sub(2).max(1)..=m_th.saturating_add(2) {
            cands.push(m);
        }
    }
    cands.sort_unstable();
    cands.dedup();
    let mut best = 1usize;
    let mut best_eff = effective_seconds_per_step_policy(
        1, step_s, stall0, drain_s, budget_per_step, lambda, recovery,
    );
    for &m in &cands {
        let eff = effective_seconds_per_step_policy(
            m, step_s, stall0, drain_s, budget_per_step, lambda, recovery,
        );
        if eff < best_eff {
            best_eff = eff;
            best = m;
        }
    }
    best
}

/// One failure-aware candidate: a planner point plus its goodput.
#[derive(Clone, Debug)]
pub struct ResilientPoint {
    pub point: PlanPoint,
    pub goodput: Goodput,
}

/// Result of a failure-aware planning query.
#[derive(Debug)]
pub struct ResilientPlanResult {
    /// The failure-free planning run — **bit-identical** to
    /// [`planner::plan`] on the same query (it IS that call; the failure
    /// model only re-ranks candidates, it never re-prices a step).
    pub base: PlanResult,
    /// The failure-aware winner (None when nothing fits).
    pub best: Option<ResilientPoint>,
    /// Did pricing failures change the winning plan?
    pub flipped: bool,
    /// The goodput search's memory-vs-effective-seconds Pareto frontier
    /// (ascending per-GPU memory, strictly descending effective seconds
    /// per useful step) — the candidates the winner was chosen from.
    /// Empty when the failure model is disabled.
    pub candidates: Vec<ResilientPoint>,
}

/// Two plan points describe the same plan (same swept knobs and
/// bit-identical pricing) — the flip test.
fn same_plan(a: &PlanPoint, b: &PlanPoint) -> bool {
    a.label() == b.label()
        && a.seconds_per_step().to_bits() == b.seconds_per_step().to_bits()
}

/// Failure-aware planning: fastest plan by **expected goodput** under
/// `fm` — one [`planner::plan_with`] pass under [`Objective::Goodput`]
/// (module docs explain why the goodput key satisfies the objective
/// contract).  Disabled model → the embedded `base` result is the answer
/// and `best` mirrors `base.best` with a unit goodput.
pub fn plan_resilient(
    model: &ModelCfg,
    cluster: &ClusterSpec,
    workload: &Workload,
    space: &PlanSpace,
    fm: &FailureModel,
    sweep: &Sweep,
    cache: &SimCache,
) -> ResilientPlanResult {
    plan_resilient_seeded(model, cluster, workload, space, fm, None, sweep, cache)
}

/// [`plan_resilient`] with an optional incumbent seed carried over from
/// a neighboring query (a what-if rung, a previous MTBF probe).  The
/// seed feeds both passes through [`planner::plan_with_seed`], which
/// revalidates and reprices it per query — results stay bit-identical
/// to the unseeded call.  With the failure model enabled, the base and
/// goodput searches run as one fused [`planner::plan_batch`]: the two
/// queries price the *same* setups (only the ranking differs), so each
/// fused wave dedups their [`crate::sweep::SetupKey`]s and every step
/// simulates once for both.
#[allow(clippy::too_many_arguments)]
pub fn plan_resilient_seeded(
    model: &ModelCfg,
    cluster: &ClusterSpec,
    workload: &Workload,
    space: &PlanSpace,
    fm: &FailureModel,
    seed: Option<&PlanSeed>,
    sweep: &Sweep,
    cache: &SimCache,
) -> ResilientPlanResult {
    if !fm.enabled_for(cluster) {
        let base = planner::plan_with_seed(
            model,
            cluster,
            workload,
            space,
            &Objective::StepTime,
            seed,
            sweep,
            cache,
        );
        let best = base.best.clone().map(|point| {
            let goodput = fm.goodput(&point.setup, point.seconds_per_step());
            ResilientPoint { point, goodput }
        });
        return ResilientPlanResult { base, best, flipped: false, candidates: Vec::new() };
    }
    let reqs = [
        planner::PlanRequest {
            model,
            cluster,
            workload,
            space,
            objective: Objective::StepTime,
            seed: seed.copied(),
        },
        planner::PlanRequest {
            model,
            cluster,
            workload,
            space,
            objective: Objective::Goodput(fm.clone()),
            seed: seed.copied(),
        },
    ];
    let mut results = planner::plan_batch(&reqs, sweep, cache);
    let good = results.pop().expect("two fused requests");
    let base = results.pop().expect("two fused requests");
    assemble_resilient(base, good, fm)
}

/// Fold a failure-free base result and a goodput-objective result into
/// the combined answer (shared by the fused and the plan-cached paths).
fn assemble_resilient(
    base: PlanResult,
    good: PlanResult,
    fm: &FailureModel,
) -> ResilientPlanResult {
    let with_goodput = |point: PlanPoint| {
        let goodput = fm.goodput(&point.setup, point.seconds_per_step());
        ResilientPoint { point, goodput }
    };
    let best = good.best.map(with_goodput);
    let candidates: Vec<ResilientPoint> =
        good.frontier.into_iter().map(with_goodput).collect();
    let flipped = match (&best, &base.best) {
        (Some(b), Some(f)) => !same_plan(&b.point, f),
        _ => false,
    };
    ResilientPlanResult { base, best, flipped, candidates }
}

/// [`plan_resilient`] behind the persistent [`PlanCache`]: both the
/// failure-free base query and the goodput query are cached whole (they
/// have distinct objective digests), so a warm repeat is two O(1)
/// lookups.  On a miss the goodput search is seeded with the base
/// winner — an in-space feasible incumbent that tightens pruning for
/// free.  Bit-identical to [`plan_resilient`] either way.
#[allow(clippy::too_many_arguments)]
pub fn plan_resilient_cached(
    model: &ModelCfg,
    cluster: &ClusterSpec,
    workload: &Workload,
    space: &PlanSpace,
    fm: &FailureModel,
    sweep: &Sweep,
    cache: &SimCache,
    plans: &PlanCache,
) -> ResilientPlanResult {
    let base = planner::plan_cached(
        model,
        cluster,
        workload,
        space,
        &Objective::StepTime,
        None,
        sweep,
        cache,
        plans,
    );
    if !fm.enabled_for(cluster) {
        let best = base.best.clone().map(|point| {
            let goodput = fm.goodput(&point.setup, point.seconds_per_step());
            ResilientPoint { point, goodput }
        });
        return ResilientPlanResult { base, best, flipped: false, candidates: Vec::new() };
    }
    let seed = base.best.as_ref().map(|b| PlanSeed::of(&b.setup));
    let good = planner::plan_cached(
        model,
        cluster,
        workload,
        space,
        &Objective::Goodput(fm.clone()),
        seed.as_ref(),
        sweep,
        cache,
        plans,
    );
    assemble_resilient(base, good, fm)
}

// ------------------------------------------------------------------
// what-if sweeps: derated fabrics, straggler jitter, MTBF ladders

/// The axis a what-if sweep derates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WhatIfAxis {
    /// Scale every node group's NIC injection bandwidth by the factor
    /// (1.0 = healthy fabric).
    Nic,
    /// Scale every node's NVLink bandwidth by the factor.
    Nvlink,
    /// Per-micro-batch compute jitter: the factor is the multiplicative
    /// spread of per-task compute times (each micro-batch task drawn
    /// uniformly in `[1 − j, 1 + j]` under a seeded stream in the
    /// timeline engine).  The plan is unchanged (the expected step is
    /// the deterministic one); the sweep point's measured p99 step time
    /// ([`SweepPoint::p99_seconds_per_step`]) carries the tail cost.
    /// Spread 0 is bit-identical to the deterministic engine.  (The
    /// older whole-node straggler reshaping survives as
    /// [`jitter_cluster`] for direct API use.)
    Jitter,
    /// The factor IS the per-node MTBF in hours (goodput ladder).
    Mtbf,
    /// The factor IS the blast-domain MTBF in hours: every declared
    /// [`ClusterSpec::domains`] level is swept to it (a cluster with no
    /// declared topology probes a default top-of-rack switch domain
    /// covering half the pod).
    DomainMtbf,
}

impl WhatIfAxis {
    pub fn parse(s: &str) -> Option<WhatIfAxis> {
        match s {
            "nic" => Some(WhatIfAxis::Nic),
            "nvlink" => Some(WhatIfAxis::Nvlink),
            "jitter" => Some(WhatIfAxis::Jitter),
            "mtbf" => Some(WhatIfAxis::Mtbf),
            "domain-mtbf" => Some(WhatIfAxis::DomainMtbf),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WhatIfAxis::Nic => "nic",
            WhatIfAxis::Nvlink => "nvlink",
            WhatIfAxis::Jitter => "jitter",
            WhatIfAxis::Mtbf => "mtbf",
            WhatIfAxis::DomainMtbf => "domain-mtbf",
        }
    }

    /// A sensible default ladder per axis (healthy end first, so the
    /// first sweep point is the baseline the boundaries compare against).
    pub fn default_factors(self) -> Vec<f64> {
        match self {
            WhatIfAxis::Nic | WhatIfAxis::Nvlink => vec![1.0, 0.5, 0.25, 0.125, 0.0625],
            WhatIfAxis::Jitter => vec![0.0, 0.2, 0.4, 0.6, 0.8],
            WhatIfAxis::Mtbf | WhatIfAxis::DomainMtbf => {
                vec![1024.0, 256.0, 64.0, 16.0, 4.0, 1.0, 0.25]
            }
        }
    }
}

/// `cluster` with every node group's NIC and/or NVLink rate scaled —
/// degraded-fabric what-ifs answered analytically instead of by
/// re-benchmarking (Kundu et al. 2024).
pub fn derate_cluster(cluster: &ClusterSpec, nic_factor: f64, nvlink_factor: f64) -> ClusterSpec {
    let mut c = cluster.clone();
    c.ib_bw *= nic_factor;
    c.node.nvlink_bw *= nvlink_factor;
    for g in &mut c.extra_groups {
        g.ib_bw *= nic_factor;
        g.node.nvlink_bw *= nvlink_factor;
    }
    c
}

/// `cluster` with ONE node turned into a straggler: its sustained
/// compute scaled by `(1 - jitter)`.  The slow node becomes its own
/// heterogeneous group at the END of placement order, so sub-pod plans
/// avoid it and only full-pod plans pay the slowest-participant price.
pub fn jitter_cluster(cluster: &ClusterSpec, jitter: f64) -> ClusterSpec {
    let mut c = cluster.clone();
    let mut slow = c.node.clone();
    slow.gpu.achievable_frac *= (1.0 - jitter).clamp(0.0, 1.0);
    if c.nodes > 1 {
        c.nodes -= 1;
        let ib_bw = c.ib_bw;
        c.extra_groups.push(NodeGroup { nodes: 1, node: slow, ib_bw });
    } else {
        c.node = slow;
    }
    c
}

/// One point of a what-if sweep: the winning plan at one derate factor.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub factor: f64,
    /// Winning plan's label (empty when nothing fits).
    pub label: String,
    /// Failure-free seconds/step of the winner.
    pub seconds_per_step: f64,
    /// Expected seconds per useful step (equals `seconds_per_step` when
    /// the failure model is disabled).
    pub effective_seconds_per_step: f64,
    /// Measured p99 seconds/step of the winner under per-micro-batch
    /// compute jitter ([`WhatIfAxis::Jitter`], seeded spread = factor).
    /// On every other axis — and at spread 0 — the deterministic engine
    /// IS the distribution, so this equals `seconds_per_step` bit for
    /// bit.
    pub p99_seconds_per_step: f64,
}

/// A factor interval where the winning plan flips: the winner at `lo`
/// differs from the winner at `hi` (consecutive ladder points).
#[derive(Clone, Debug)]
pub struct PhaseBoundary {
    pub lo: f64,
    pub hi: f64,
    pub from: String,
    pub to: String,
}

/// Replan at every factor of `axis` and report the winner per point.
/// With `fm` enabled (or the rung's cluster carrying enabled blast
/// domains) the winner is the failure-aware one; for the
/// [`WhatIfAxis::Mtbf`] axis each factor *is* the per-node MTBF in
/// hours, for [`WhatIfAxis::DomainMtbf`] the blast-domain MTBF, and for
/// [`WhatIfAxis::Jitter`] the per-micro-batch compute spread whose
/// measured p99 step time lands in
/// [`SweepPoint::p99_seconds_per_step`].
///
/// The ladder is incremental and fused (bit-identical to replanning each
/// rung cold): rung 0 runs alone and its winner becomes the **incumbent
/// seed** for every other rung (revalidated and repriced per rung — a
/// winner that stops fitting under a harsher derate is discarded, never
/// trusted), and rungs 1..n run as ONE [`planner::plan_batch`] of shared
/// pricing waves, so the pool stays occupied across the whole ladder.
/// Only the winner-ranking search runs per rung — a sweep point never
/// reads the failure-free base pass the old per-rung
/// [`plan_resilient`] call also computed.
pub fn whatif_sweep(
    model: &ModelCfg,
    cluster: &ClusterSpec,
    workload: &Workload,
    space: &PlanSpace,
    axis: WhatIfAxis,
    factors: &[f64],
    fm: &FailureModel,
    sweep: &Sweep,
    cache: &SimCache,
) -> Vec<SweepPoint> {
    if factors.is_empty() {
        return Vec::new();
    }
    // per-rung query inputs: the derated cluster and the rung's failure
    // model (the Mtbf axis sweeps the model itself, the DomainMtbf axis
    // the cluster's blast-domain topology).  The jitter axis plans on
    // the unperturbed cluster at every rung — the expected step is the
    // deterministic one and plan_batch dedups the identical queries —
    // and prices the rung's tail separately below.
    let queries: Vec<(ClusterSpec, FailureModel)> = factors
        .iter()
        .map(|&factor| match axis {
            WhatIfAxis::Nic => (derate_cluster(cluster, factor, 1.0), fm.clone()),
            WhatIfAxis::Nvlink => (derate_cluster(cluster, 1.0, factor), fm.clone()),
            WhatIfAxis::Jitter => (cluster.clone(), fm.clone()),
            WhatIfAxis::Mtbf => {
                (cluster.clone(), FailureModel { mtbf_hours: factor, ..fm.clone() })
            }
            WhatIfAxis::DomainMtbf => {
                let mut c = cluster.clone();
                if c.domains.is_empty() {
                    // no declared topology: probe a default top-of-rack
                    // switch domain covering half the pod
                    let size = (c.total_nodes() + 1) / 2;
                    c.domains.push(BlastDomain {
                        name: "switch".into(),
                        size: size.max(1),
                        mtbf_hours: factor,
                    });
                } else {
                    for d in &mut c.domains {
                        d.mtbf_hours = factor;
                    }
                }
                (c, fm.clone())
            }
        })
        .collect();
    let rung_objective = |c: &ClusterSpec, pfm: &FailureModel| {
        if pfm.enabled_for(c) {
            Objective::Goodput(pfm.clone())
        } else {
            Objective::StepTime
        }
    };
    // rung 0: cold; its winner seeds the rest of the ladder
    let first = {
        let (c, pfm) = &queries[0];
        planner::plan_with_seed(
            model,
            c,
            workload,
            space,
            &rung_objective(c, pfm),
            None,
            sweep,
            cache,
        )
    };
    let seed = first.best.as_ref().map(|b| PlanSeed::of(&b.setup));
    // rungs 1..n: one fused batch, every rung incumbent-seeded
    let objectives: Vec<Objective> =
        queries[1..].iter().map(|(c, pfm)| rung_objective(c, pfm)).collect();
    let reqs: Vec<planner::PlanRequest<'_>> = queries[1..]
        .iter()
        .zip(&objectives)
        .map(|((c, _), objective)| planner::PlanRequest {
            model,
            cluster: c,
            workload,
            space,
            objective: objective.clone(),
            seed,
        })
        .collect();
    let rest = planner::plan_batch(&reqs, sweep, cache);
    std::iter::once(first)
        .chain(rest)
        .zip(factors)
        .zip(&queries)
        .map(|((r, &factor), (_, pfm))| match r.best {
            Some(b) => {
                let seconds = b.seconds_per_step();
                let effective = if pfm.enabled_for(&b.setup.cluster) {
                    pfm.goodput(&b.setup, seconds).effective_seconds_per_step
                } else {
                    seconds
                };
                // jitter rungs re-price the winner's step under seeded
                // per-micro-batch spread; spread 0 and every other axis
                // return the deterministic step bit-identically
                let p99 = if axis == WhatIfAxis::Jitter && factor > 0.0 {
                    sim::jittered_step_stats(&b.setup, JITTER_SEED, factor, JITTER_SAMPLES)
                        .p99_s
                } else {
                    seconds
                };
                SweepPoint {
                    factor,
                    label: b.label(),
                    seconds_per_step: seconds,
                    effective_seconds_per_step: effective,
                    p99_seconds_per_step: p99,
                }
            }
            None => SweepPoint {
                factor,
                label: String::new(),
                seconds_per_step: f64::INFINITY,
                effective_seconds_per_step: f64::INFINITY,
                p99_seconds_per_step: f64::INFINITY,
            },
        })
        .collect()
}

/// The intervals of a sweep where the winning plan flips.
pub fn phase_boundaries(points: &[SweepPoint]) -> Vec<PhaseBoundary> {
    let mut out = Vec::new();
    for w in points.windows(2) {
        if w[0].label != w[1].label {
            out.push(PhaseBoundary {
                lo: w[0].factor,
                hi: w[1].factor,
                from: w[0].label.clone(),
                to: w[1].label.clone(),
            });
        }
    }
    out
}

/// Scan a descending MTBF ladder and return the first MTBF (hours) where
/// the failure-aware winner differs from the failure-free winner, with
/// the full result at that point.  `None` when even the harshest rung
/// never flips (e.g. the failure-free winner already runs on 1 node).
pub fn find_flip(
    model: &ModelCfg,
    cluster: &ClusterSpec,
    workload: &Workload,
    space: &PlanSpace,
    fm: &FailureModel,
    sweep: &Sweep,
    cache: &SimCache,
) -> Option<(f64, ResilientPlanResult)> {
    // log-spaced, from "monthly" failures down to pathological churn —
    // the flip point only has to exist somewhere on the ladder.  Each
    // rung seeds the next with its goodput winner (revalidated and
    // repriced per rung), so the descent gets cheaper as it goes while
    // staying bit-identical to cold per-rung replans.
    const LADDER: [f64; 9] = [512.0, 128.0, 32.0, 8.0, 2.0, 0.5, 0.125, 0.03125, 0.0078125];
    let mut seed: Option<PlanSeed> = None;
    for &mtbf in &LADDER {
        let probe = FailureModel { mtbf_hours: mtbf, ..fm.clone() };
        let r = plan_resilient_seeded(
            model,
            cluster,
            workload,
            space,
            &probe,
            seed.as_ref(),
            sweep,
            cache,
        );
        if r.flipped {
            return Some((mtbf, r));
        }
        seed = r.best.as_ref().map(|b| PlanSeed::of(&b.point.setup));
    }
    None
}

// ------------------------------------------------------------------
// elastic re-planning: drop k nodes, replan on the survivors

/// An elastic recovery plan after `dropped` nodes fail at once.
#[derive(Debug)]
pub struct ElasticReplan {
    /// Nodes left after the failure.
    pub survivors: usize,
    /// Failure-aware plan on the survivor cluster.
    pub result: ResilientPlanResult,
    /// One-time cost of getting back to useful work on the new plan:
    /// checkpoint restore + restart overhead + expected rework (half the
    /// new plan's checkpoint interval — the steady-state expected loss
    /// since the last checkpoint).
    pub restart_cost_s: f64,
}

/// Dropping `dropped` nodes leaves no cluster that can run the model:
/// either no node survives at all, or no plan fits the survivor pod.
/// Surfaced as a structured, typed error (`error_kind:
/// "cluster_exhausted"` on the serve and CLI front-ends) instead of a
/// panic or an empty plan.
#[derive(Clone, Debug)]
pub struct ClusterExhausted {
    pub total_nodes: usize,
    pub dropped: usize,
    /// Nodes left after the drop (0 when `dropped >= total_nodes`).
    pub survivors: usize,
}

impl std::fmt::Display for ClusterExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.survivors == 0 {
            write!(
                f,
                "cannot drop {} of {} nodes: no survivors",
                self.dropped, self.total_nodes
            )
        } else {
            write!(
                f,
                "dropping {} of {} nodes leaves {} survivor node(s) but no feasible plan",
                self.dropped, self.total_nodes, self.survivors
            )
        }
    }
}

impl std::error::Error for ClusterExhausted {}

/// Drop `dropped` nodes from `cluster` (placement order: weakest extra
/// groups go first — [`ClusterSpec::take_nodes`] keeps the primary
/// group), replan on the survivors, and price the restart from the last
/// checkpoint.  Returns the typed [`ClusterExhausted`] error when no
/// node survives or no plan fits the survivor pod (the `?` operator
/// still converts it into `anyhow::Result` for callers that don't
/// match on it).
pub fn replan_after_failure(
    model: &ModelCfg,
    cluster: &ClusterSpec,
    workload: &Workload,
    space: &PlanSpace,
    fm: &FailureModel,
    dropped: usize,
    sweep: &Sweep,
    cache: &SimCache,
) -> Result<ElasticReplan, ClusterExhausted> {
    let total = cluster.total_nodes();
    if dropped >= total {
        return Err(ClusterExhausted { total_nodes: total, dropped, survivors: 0 });
    }
    let survivors = total - dropped;
    let surviving = cluster.take_nodes(survivors);
    let result = plan_resilient(model, &surviving, workload, space, fm, sweep, cache);
    if result.best.is_none() {
        return Err(ClusterExhausted { total_nodes: total, dropped, survivors });
    }
    let restart_cost_s = match &result.best {
        Some(b) => {
            let ckpt = fm.checkpoint_cost(&b.point.setup);
            let rework = if fm.enabled() && b.goodput.interval_steps > 0 {
                let w = b.goodput.interval_steps as f64 * b.point.seconds_per_step()
                    + ckpt.write_s;
                w / 2.0
            } else {
                0.0
            };
            ckpt.restore_s + fm.restart_overhead_s + rework
        }
        None => f64::INFINITY,
    };
    Ok(ElasticReplan { survivors, result, restart_cost_s })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;
    use crate::zero::OptimizerKind;

    fn small_space() -> PlanSpace {
        // a thin but multi-node slice of the default space: enough to
        // exercise the (n, opt) decomposition without long pricing
        PlanSpace {
            optimizers: vec![OptimizerKind::AdamW, OptimizerKind::Adafactor],
            micro_batch_caps: vec![0, 8],
            schedules: vec![crate::parallel::PipeSchedule::OneFOneB],
            nodes: vec![1, 2, 4],
            max_tp: 4,
            max_pp: 2,
            max_sp: 1,
            max_ep: 1,
            ..PlanSpace::default()
        }
    }

    #[test]
    fn interval_optimal_vs_brute_force() {
        // a grid over the interesting regimes: cheap/expensive
        // checkpoints, rare/frequent failures, fast/slow steps
        for &step_s in &[0.5, 2.0, 30.0] {
            for &delta in &[1.0, 30.0, 600.0] {
                for &mtbf_s in &[900.0, 3600.0 * 24.0, 3600.0 * 24.0 * 30.0] {
                    for &recovery in &[30.0, 600.0] {
                        let lambda = 8.0 / mtbf_s;
                        let m = optimal_interval_steps(step_s, delta, lambda, recovery);
                        let eff = effective_seconds_per_step(m, step_s, delta, lambda, recovery);
                        for cand in 1..=20_000usize {
                            let e = effective_seconds_per_step(
                                cand, step_s, delta, lambda, recovery,
                            );
                            assert!(
                                eff <= e * (1.0 + 1e-12),
                                "s={step_s} δ={delta} λ={lambda:.2e} R={recovery}: \
                                 m={m} ({eff}) beaten by m={cand} ({e})"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn interval_follows_young_scaling() {
        // τ* = sqrt(2δ/λ): quadrupling δ or quartering λ doubles the
        // optimal interval, roughly (integer effects aside)
        let s = 1.0;
        let base = optimal_interval_steps(s, 10.0, 1e-4, 100.0);
        let big_delta = optimal_interval_steps(s, 40.0, 1e-4, 100.0);
        let rare = optimal_interval_steps(s, 10.0, 2.5e-5, 100.0);
        assert!(base >= 2, "base interval too small to test scaling: {base}");
        for (name, v) in [("4x delta", big_delta), ("lambda/4", rare)] {
            let ratio = v as f64 / base as f64;
            assert!(
                (1.6..=2.6).contains(&ratio),
                "{name}: interval {v} vs base {base} (ratio {ratio})"
            );
        }
    }

    #[test]
    fn goodput_monotone_in_mtbf() {
        let model = by_name("mt5-large").unwrap();
        let setup = TrainSetup::dp_pod(model, 4, crate::zero::ZeroStage::Stage2);
        let step_s = crate::sim::simulate_step(&setup).seconds_per_step();
        let mut prev = 0.0;
        for mtbf in [0.25, 1.0, 4.0, 16.0, 64.0, 256.0, 1024.0] {
            let g = FailureModel::with_mtbf(mtbf).goodput(&setup, step_s);
            assert!(
                g.goodput_fraction > prev,
                "goodput not monotone in MTBF: {} at {mtbf}h after {prev}",
                g.goodput_fraction
            );
            assert!(g.goodput_fraction < 1.0);
            assert!(g.effective_seconds_per_step > step_s);
            prev = g.goodput_fraction;
        }
        // disabled model: exactly 1.0, effective == step time bit-for-bit
        let off = FailureModel::disabled().goodput(&setup, step_s);
        assert_eq!(off.goodput_fraction, 1.0);
        assert_eq!(off.effective_seconds_per_step.to_bits(), step_s.to_bits());
        assert_eq!(off.interval_steps, 0);
    }

    #[test]
    fn checkpoint_bytes_follow_optimizer_state() {
        let model = by_name("mt5-xl").unwrap();
        let fm = FailureModel::with_mtbf(24.0);
        let mut adamw = TrainSetup::dp_pod(model.clone(), 4, crate::zero::ZeroStage::Stage2);
        adamw.opt = OptimizerKind::AdamW;
        let mut ada = adamw.clone();
        ada.opt = OptimizerKind::Adafactor;
        let ca = fm.checkpoint_cost(&adamw);
        let cf = fm.checkpoint_cost(&ada);
        let psi = model.params() as f64;
        assert!((ca.bytes - 14.0 * psi).abs() < 1.0, "adamw: {}", ca.bytes);
        assert!((cf.bytes - 6.5 * psi).abs() < 1.0, "adafactor: {}", cf.bytes);
        assert!(ca.write_s > cf.write_s);
        // more writers against the shared ceiling: 8 nodes no slower
        let wide = TrainSetup::dp_pod(model, 8, crate::zero::ZeroStage::Stage2);
        assert!(fm.checkpoint_cost(&wide).write_s <= ca.write_s);
    }

    #[test]
    fn disabled_model_embeds_plain_plan_bit_identically() {
        let model = by_name("mt5-large").unwrap();
        let cluster = ClusterSpec::lps_pod(4);
        let w = Workload::table1();
        let space = small_space();
        let cache = SimCache::new();
        let sweep = Sweep::serial();
        let plain = planner::plan(&model, &cluster, &w, &space, &sweep, &cache);
        let r = plan_resilient(
            &model,
            &cluster,
            &w,
            &space,
            &FailureModel::disabled(),
            &sweep,
            &cache,
        );
        assert!(!r.flipped);
        assert!(r.candidates.is_empty(), "disabled model must not replan slices");
        let (a, b) = (plain.best.as_ref().unwrap(), r.base.best.as_ref().unwrap());
        assert_eq!(a.label(), b.label());
        assert_eq!(a.seconds_per_step().to_bits(), b.seconds_per_step().to_bits());
        assert_eq!(plain.frontier.len(), r.base.frontier.len());
        let best = r.best.as_ref().unwrap();
        assert_eq!(best.point.label(), b.label());
        assert_eq!(best.goodput.goodput_fraction, 1.0);
    }

    #[test]
    fn slice_decomposition_covers_the_failure_free_winner() {
        // at an effectively-infinite MTBF the failure-aware winner must
        // coincide with the failure-free best (goodput ≈ monotone in s)
        let model = by_name("mt5-large").unwrap();
        let cluster = ClusterSpec::lps_pod(4);
        let w = Workload::table1();
        let space = small_space();
        let cache = SimCache::new();
        let fm = FailureModel::with_mtbf(1.0e9);
        let r = plan_resilient(&model, &cluster, &w, &space, &fm, &Sweep::serial(), &cache);
        assert!(!r.flipped, "a ~infinite MTBF must not flip the plan");
        assert!(!r.candidates.is_empty());
        let best = r.best.as_ref().unwrap();
        let base = r.base.best.as_ref().unwrap();
        assert!(same_plan(&best.point, base));
        assert!(best.goodput.goodput_fraction > 0.999);
    }

    #[test]
    fn blast_radius_flips_the_plan_under_harsh_mtbf() {
        let model = by_name("mt5-large").unwrap();
        let cluster = ClusterSpec::lps_pod(4);
        let w = Workload::table1();
        let space = small_space();
        let cache = SimCache::new();
        let sweep = Sweep::serial();
        let base = planner::plan(&model, &cluster, &w, &space, &sweep, &cache);
        let base_nodes = base.best.as_ref().unwrap().setup.cluster.total_nodes();
        assert!(
            base_nodes > 1,
            "flip premise: the failure-free winner must be a multi-node plan"
        );
        // a crawling shared store: δ = C/B is constant in the node count
        // and dwarfs the step time, so at harsh MTBFs the cluster failure
        // rate (∝ nodes) dominates and a narrower plan must win
        let fm = FailureModel {
            mtbf_hours: 0.0, // ladder probes set it
            write_bw: 2e9,
            read_bw: 2e9,
            shared_bw: 1e8,
            restart_overhead_s: 120.0,
            policy: CheckpointPolicy::Sync,
        };
        let (mtbf, flip) = find_flip(&model, &cluster, &w, &space, &fm, &sweep, &cache)
            .expect("some MTBF on the ladder must flip a multi-node winner");
        assert!(flip.flipped);
        let winner = flip.best.as_ref().unwrap();
        let flip_nodes = winner.point.setup.cluster.total_nodes();
        assert!(
            flip_nodes < base_nodes,
            "at MTBF {mtbf}h the winner should shrink its blast radius \
             ({flip_nodes} vs {base_nodes} nodes)"
        );
        // and the winner's expected goodput beats the failure-free best's
        let base_gp = fm_at(mtbf, &fm)
            .goodput(&base.best.as_ref().unwrap().setup, base.best.as_ref().unwrap().seconds_per_step());
        assert!(
            winner.goodput.effective_seconds_per_step
                < base_gp.effective_seconds_per_step,
            "winner must beat the failure-free best under the same failure model"
        );
    }

    fn fm_at(mtbf: f64, fm: &FailureModel) -> FailureModel {
        FailureModel { mtbf_hours: mtbf, ..fm.clone() }
    }

    #[test]
    fn derate_and_jitter_reshape_the_cluster() {
        let cluster = ClusterSpec::lps_pod(4);
        let d = derate_cluster(&cluster, 0.5, 0.25);
        assert_eq!(d.ib_bw, cluster.ib_bw * 0.5);
        assert_eq!(d.node.nvlink_bw, cluster.node.nvlink_bw * 0.25);
        assert_eq!(d.total_nodes(), 4);
        let j = jitter_cluster(&cluster, 0.5);
        assert_eq!(j.total_nodes(), 4, "jitter must not change the node count");
        assert_eq!(j.nodes, 3);
        assert_eq!(j.extra_groups.len(), 1);
        let frac = j.extra_groups[0].node.gpu.achievable_frac;
        assert!((frac - cluster.node.gpu.achievable_frac * 0.5).abs() < 1e-12);
        // take_nodes(3) avoids the straggler entirely
        let sub = j.take_nodes(3);
        assert!(sub.extra_groups.is_empty());
        // single-node cluster: the one node itself slows down
        let j1 = jitter_cluster(&ClusterSpec::lps_pod(1), 0.3);
        assert_eq!(j1.total_nodes(), 1);
        assert!(j1.node.gpu.achievable_frac < ClusterSpec::lps_pod(1).node.gpu.achievable_frac);
    }

    #[test]
    fn whatif_nic_sweep_slows_plans_and_reports_boundaries() {
        let model = by_name("mt5-large").unwrap();
        let cluster = ClusterSpec::lps_pod(2);
        let w = Workload::table1();
        let space = PlanSpace { nodes: vec![1, 2], ..small_space() };
        let cache = SimCache::new();
        let pts = whatif_sweep(
            &model,
            &cluster,
            &w,
            &space,
            WhatIfAxis::Nic,
            &[1.0, 0.25, 0.01],
            &FailureModel::disabled(),
            &Sweep::serial(),
            &cache,
        );
        assert_eq!(pts.len(), 3);
        assert!(!pts[0].label.is_empty());
        // a derated fabric can never speed the winner up
        assert!(pts[1].seconds_per_step >= pts[0].seconds_per_step - 1e-12);
        assert!(pts[2].seconds_per_step >= pts[1].seconds_per_step - 1e-12);
        // boundaries are exactly the label changes, whatever they are
        let bounds = phase_boundaries(&pts);
        let changes = pts.windows(2).filter(|w| w[0].label != w[1].label).count();
        assert_eq!(bounds.len(), changes);
        for b in &bounds {
            assert_ne!(b.from, b.to);
        }
    }

    #[test]
    fn elastic_replan_prices_survivors_and_restart() {
        let model = by_name("mt5-large").unwrap();
        let cluster = ClusterSpec::lps_pod(4);
        let w = Workload::table1();
        let space = small_space();
        let cache = SimCache::new();
        let fm = FailureModel::with_mtbf(64.0);
        let r = replan_after_failure(
            &model,
            &cluster,
            &w,
            &space,
            &fm,
            1,
            &Sweep::serial(),
            &cache,
        )
        .unwrap();
        assert_eq!(r.survivors, 3);
        let best = r.result.best.as_ref().expect("survivors still fit the model");
        assert!(best.point.setup.cluster.total_nodes() <= 3);
        // restart = restore + overhead + expected rework: strictly more
        // than the bare restore time
        let restore = fm.checkpoint_cost(&best.point.setup).restore_s;
        assert!(r.restart_cost_s > restore + fm.restart_overhead_s - 1e-9);
        assert!(r.restart_cost_s.is_finite());
        // dropping everything is an error
        assert!(replan_after_failure(
            &model,
            &cluster,
            &w,
            &space,
            &fm,
            4,
            &Sweep::serial(),
            &cache,
        )
        .is_err());
    }

    #[test]
    fn cluster_exhausted_error_is_typed_and_structured() {
        let model = by_name("mt5-large").unwrap();
        let cluster = ClusterSpec::lps_pod(4);
        let w = Workload::table1();
        let space = small_space();
        let cache = SimCache::new();
        let err = replan_after_failure(
            &model,
            &cluster,
            &w,
            &space,
            &FailureModel::with_mtbf(64.0),
            7,
            &Sweep::serial(),
            &cache,
        )
        .unwrap_err();
        assert_eq!((err.total_nodes, err.dropped, err.survivors), (4, 7, 0));
        assert!(err.to_string().contains("no survivors"), "{err}");
        // the typed error still converts into anyhow via `?`
        let as_anyhow: anyhow::Error = err.into();
        assert!(as_anyhow.to_string().contains("no survivors"));
    }

    #[test]
    fn empty_topology_sync_policy_bit_identical_to_pr7_on_every_zoo_model() {
        // the PR 7 closed form, inlined: with no blast domains and the
        // Sync policy, goodput() must reproduce λ = n/MTBF, the blocking
        // write cost, and the synchronous interval optimum bit for bit
        for model in crate::model::mt5_zoo() {
            let setup = TrainSetup::dp_pod(model, 4, crate::zero::ZeroStage::Stage2);
            let step_s = crate::sim::simulate_step(&setup).seconds_per_step();
            if !step_s.is_finite() {
                continue; // a shape that does not fit has no goodput story
            }
            for mtbf in [0.25, 4.0, 64.0, 1024.0] {
                let fm = FailureModel::with_mtbf(mtbf);
                let g = fm.goodput(&setup, step_s);
                let ckpt = fm.checkpoint_cost(&setup);
                assert_eq!(ckpt.drain_s.to_bits(), 0.0f64.to_bits());
                let lambda =
                    setup.cluster.total_nodes().max(1) as f64 / (mtbf * HOUR_S);
                let recovery = ckpt.restore_s + fm.restart_overhead_s;
                let m = optimal_interval_steps(step_s, ckpt.write_s, lambda, recovery);
                let eff =
                    effective_seconds_per_step(m, step_s, ckpt.write_s, lambda, recovery);
                assert_eq!(g.interval_steps, m);
                assert_eq!(g.lambda_per_s.to_bits(), lambda.to_bits());
                assert_eq!(g.checkpoint_write_s.to_bits(), ckpt.write_s.to_bits());
                assert_eq!(g.effective_seconds_per_step.to_bits(), eff.to_bits());
                assert_eq!(g.goodput_fraction.to_bits(), (step_s / eff).to_bits());
            }
        }
    }

    #[test]
    fn topology_levels_sum_to_lambda_for() {
        let mut cluster = ClusterSpec::lps_pod(8);
        cluster.domains.push(BlastDomain {
            name: "switch".into(),
            size: 4,
            mtbf_hours: 200.0,
        });
        cluster.domains.push(BlastDomain { name: "rack".into(), size: 8, mtbf_hours: 1000.0 });
        cluster.domains.push(BlastDomain { name: "off".into(), size: 2, mtbf_hours: 0.0 });
        let fm = FailureModel::with_mtbf(100.0);
        let topo = fm.topology(&cluster);
        assert_eq!(topo.levels.len(), 3, "node + 2 enabled levels; disabled level dropped");
        assert_eq!(
            topo.total_lambda_per_s().to_bits(),
            fm.lambda_for(&cluster).to_bits(),
            "per-level rates must sum to the aggregate, bit for bit"
        );
        // sub-pods span fewer domain instances
        let sub = cluster.take_nodes(2);
        assert!(fm.lambda_for(&sub) < fm.lambda_for(&cluster));
        assert_eq!(fm.topology(&sub).levels[1].instances, 1);
        // a domain-only model (node term disabled) still fires
        let off = FailureModel::disabled();
        assert!(off.enabled_for(&cluster));
        assert!(off.lambda_for(&cluster) > 0.0);
        assert!(!off.enabled_for(&ClusterSpec::lps_pod(8)));
        // empty domains: exactly the PR 7 node rate
        assert_eq!(
            fm.lambda_for(&ClusterSpec::lps_pod(8)).to_bits(),
            fm.lambda_per_s(8).to_bits()
        );
    }

    #[test]
    fn domain_boundaries_step_the_interruption_rate() {
        let mut cluster = ClusterSpec::lps_pod(8);
        cluster.domains.push(BlastDomain {
            name: "switch".into(),
            size: 4,
            mtbf_hours: 100.0,
        });
        let fm = FailureModel::with_mtbf(1000.0);
        let l: Vec<f64> = (1..=8).map(|n| fm.lambda_for(&cluster.take_nodes(n))).collect();
        for w in l.windows(2) {
            assert!(w[1] >= w[0], "rate must be monotone in the node count: {l:?}");
        }
        // within a switch, growing the plan pays only the node term;
        // crossing the 4 -> 5 boundary adds a whole new switch instance
        let within = l[3] - l[2];
        let crossing = l[4] - l[3];
        assert!(
            crossing > within * 5.0,
            "boundary step must dominate the node term: {within} vs {crossing}"
        );
    }

    #[test]
    fn policy_interval_optimal_vs_brute_force() {
        // async/tiered grid: snapshot stall, drained persist, per-step
        // overlap budget — the piecewise optimizer must match brute force
        for &step_s in &[0.5, 2.0, 30.0] {
            let budget = crate::timeline::checkpoint_drain_budget(step_s);
            for &stall0 in &[0.0, 1.0, 30.0] {
                for &drain_s in &[5.0, 120.0, 3600.0] {
                    for &mtbf_s in &[900.0, 3600.0 * 24.0, 3600.0 * 24.0 * 30.0] {
                        for &recovery in &[30.0, 600.0] {
                            let lambda = 8.0 / mtbf_s;
                            let m = optimal_interval_steps_policy(
                                step_s, stall0, drain_s, budget, lambda, recovery,
                            );
                            let eff = effective_seconds_per_step_policy(
                                m, step_s, stall0, drain_s, budget, lambda, recovery,
                            );
                            for cand in 1..=20_000usize {
                                let e = effective_seconds_per_step_policy(
                                    cand, step_s, stall0, drain_s, budget, lambda, recovery,
                                );
                                assert!(
                                    eff <= e * (1.0 + 1e-12),
                                    "s={step_s} δ0={stall0} drain={drain_s} λ={lambda:.2e} \
                                     R={recovery}: m={m} ({eff}) beaten by m={cand} ({e})"
                                );
                            }
                        }
                    }
                }
            }
        }
        // zero drain routes to the synchronous optimizer, same bits
        let (s, d, l, r) = (2.0, 30.0, 1e-4, 120.0);
        let b = crate::timeline::checkpoint_drain_budget(s);
        assert_eq!(
            optimal_interval_steps_policy(s, d, 0.0, b, l, r),
            optimal_interval_steps(s, d, l, r)
        );
        let m = optimal_interval_steps(s, d, l, r);
        assert_eq!(
            effective_seconds_per_step_policy(m, s, d, 0.0, b, l, r).to_bits(),
            effective_seconds_per_step(m, s, d, l, r).to_bits()
        );
    }

    #[test]
    fn async_and_tiered_policies_shrink_the_critical_path() {
        let model = by_name("mt5-xl").unwrap();
        let setup = TrainSetup::dp_pod(model, 4, crate::zero::ZeroStage::Stage2);
        let step_s = crate::sim::simulate_step(&setup).seconds_per_step();
        assert!(step_s.is_finite());
        // a crawling shared store makes the blocking write expensive
        let sync = FailureModel { shared_bw: 1e8, ..FailureModel::with_mtbf(4.0) };
        let async_fm = FailureModel {
            policy: CheckpointPolicy::Async { snapshot_s: 2.0, drain_bw: 2e9 },
            ..sync.clone()
        };
        let cs = sync.checkpoint_cost(&setup);
        let ca = async_fm.checkpoint_cost(&setup);
        assert!(ca.write_s < cs.write_s, "snapshot stall must undercut the blocking write");
        assert_eq!(cs.drain_s, 0.0);
        assert!(ca.drain_s > 0.0);
        let gs = sync.goodput(&setup, step_s);
        let ga = async_fm.goodput(&setup, step_s);
        assert!(
            ga.goodput_fraction > gs.goodput_fraction,
            "draining the persist must beat blocking on it: {} vs {}",
            ga.goodput_fraction,
            gs.goodput_fraction
        );
        // tiered + replicate: local NVMe stall, shared drain, and node
        // failures restore from the buddy's local shard
        let tiered = FailureModel {
            policy: CheckpointPolicy::Tiered {
                local_bw: 5e9,
                shared_bw: 1e8,
                replicate: true,
            },
            ..sync.clone()
        };
        let ct = tiered.checkpoint_cost(&setup);
        assert!(ct.write_s < cs.write_s);
        assert!(ct.restore_s < cs.restore_s, "node failures restore from the local tier");
        // un-replicated: every restore falls back to the shared tier
        let bare = FailureModel {
            policy: CheckpointPolicy::Tiered {
                local_bw: 5e9,
                shared_bw: 1e8,
                replicate: false,
            },
            ..sync.clone()
        };
        assert!(bare.checkpoint_cost(&setup).restore_s > ct.restore_s);
        // a domain-dominated topology pushes the replicated restore back
        // toward the shared tier (the whole local tier dies with the
        // domain)
        let mut dsetup = setup.clone();
        dsetup.cluster.domains.push(BlastDomain {
            name: "switch".into(),
            size: 4,
            mtbf_hours: 1.0,
        });
        assert!(tiered.checkpoint_cost(&dsetup).restore_s > ct.restore_s);
    }

    #[test]
    fn correlated_domains_rerank_differently_than_independent_at_equal_rate() {
        // the regression only correlated domains can produce: at the SAME
        // full-cluster aggregate interruption rate, the independent
        // Poisson model shrinks the blast radius (λ ∝ nodes rewards
        // narrow plans) while the correlated model keeps the wide winner
        // (1..=4 nodes all sit behind the same switch, so shrinking buys
        // no rate reduction, only a slower step)
        let model = by_name("mt5-large").unwrap();
        let w = Workload::table1();
        let space = small_space();
        let sweep = Sweep::serial();
        let base_fm = FailureModel {
            mtbf_hours: 0.0, // correlated probe: node term disabled
            write_bw: 2e9,
            read_bw: 2e9,
            shared_bw: 1e8, // crawling shared store: δ constant in nodes
            restart_overhead_s: 120.0,
            policy: CheckpointPolicy::Sync,
        };
        let plain = ClusterSpec::lps_pod(4);
        let mut found = None;
        for &domain_mtbf in &[2.0, 0.5, 0.125, 0.03125, 0.0078125] {
            let mut corr_cluster = plain.clone();
            corr_cluster.domains.push(BlastDomain {
                name: "switch".into(),
                size: 4,
                mtbf_hours: domain_mtbf,
            });
            // independent probe: per-node MTBF chosen so the full-pod
            // aggregate rate matches the correlated model
            let ind_fm = FailureModel { mtbf_hours: 4.0 * domain_mtbf, ..base_fm.clone() };
            let l_corr = base_fm.lambda_for(&corr_cluster);
            let l_ind = ind_fm.lambda_for(&plain);
            assert!(
                ((l_corr - l_ind) / l_ind).abs() < 1e-9,
                "aggregate rates must match: {l_corr} vs {l_ind}"
            );
            let cache = SimCache::new();
            let corr =
                plan_resilient(&model, &corr_cluster, &w, &space, &base_fm, &sweep, &cache);
            let ind = plan_resilient(&model, &plain, &w, &space, &ind_fm, &sweep, &cache);
            let (cb, ib) = (corr.best.as_ref().unwrap(), ind.best.as_ref().unwrap());
            let corr_nodes = cb.point.setup.cluster.total_nodes();
            let ind_nodes = ib.point.setup.cluster.total_nodes();
            assert!(
                corr_nodes >= ind_nodes,
                "the correlated model must never prefer a narrower plan than \
                 the independent one at equal aggregate rate"
            );
            if ind_nodes < corr_nodes {
                found = Some((domain_mtbf, corr_nodes, ind_nodes));
                break;
            }
        }
        let (mtbf, corr_nodes, ind_nodes) = found.expect(
            "some rung must re-rank: independent-Poisson shrinks the blast \
             radius while the correlated model keeps the wide plan",
        );
        assert!(ind_nodes < corr_nodes, "at domain MTBF {mtbf}h: {ind_nodes} vs {corr_nodes}");
    }

    #[test]
    fn whatif_domain_mtbf_axis_prices_topology() {
        let model = by_name("mt5-large").unwrap();
        let cluster = ClusterSpec::lps_pod(2);
        let w = Workload::table1();
        let space = PlanSpace { nodes: vec![1, 2], ..small_space() };
        let cache = SimCache::new();
        let pts = whatif_sweep(
            &model,
            &cluster,
            &w,
            &space,
            WhatIfAxis::DomainMtbf,
            &[1024.0, 1.0, 0.0625],
            &FailureModel::disabled(),
            &Sweep::serial(),
            &cache,
        );
        assert_eq!(pts.len(), 3);
        for p in &pts {
            assert!(!p.label.is_empty());
            assert!(
                p.effective_seconds_per_step > p.seconds_per_step,
                "domain failures must be priced even with the node term disabled"
            );
        }
        // a harsher domain MTBF strictly raises every candidate's rate,
        // so the winner's effective step can only worsen
        assert!(pts[1].effective_seconds_per_step > pts[0].effective_seconds_per_step);
        assert!(pts[2].effective_seconds_per_step > pts[1].effective_seconds_per_step);
    }

    #[test]
    fn whatif_jitter_axis_measures_p99_and_degenerates_at_zero() {
        let model = by_name("mt5-large").unwrap();
        let cluster = ClusterSpec::lps_pod(2);
        let w = Workload::table1();
        let space = PlanSpace { nodes: vec![1, 2], ..small_space() };
        let cache = SimCache::new();
        let pts = whatif_sweep(
            &model,
            &cluster,
            &w,
            &space,
            WhatIfAxis::Jitter,
            &[0.0, 0.3],
            &FailureModel::disabled(),
            &Sweep::serial(),
            &cache,
        );
        assert_eq!(pts.len(), 2);
        // spread 0: the deterministic engine IS the distribution
        assert_eq!(pts[0].p99_seconds_per_step.to_bits(), pts[0].seconds_per_step.to_bits());
        // the plan is the unperturbed one on every rung (the expected
        // step is deterministic; only the measured tail moves)
        assert_eq!(pts[0].label, pts[1].label);
        assert_eq!(pts[0].seconds_per_step.to_bits(), pts[1].seconds_per_step.to_bits());
        // the measured tail sits at or above the deterministic step
        assert!(pts[1].p99_seconds_per_step >= pts[1].seconds_per_step - 1e-12);
        // non-jitter axes carry the deterministic step as their p99
        let nic = whatif_sweep(
            &model,
            &cluster,
            &w,
            &space,
            WhatIfAxis::Nic,
            &[1.0, 0.5],
            &FailureModel::disabled(),
            &Sweep::serial(),
            &cache,
        );
        for p in &nic {
            assert_eq!(p.p99_seconds_per_step.to_bits(), p.seconds_per_step.to_bits());
        }
    }
}
