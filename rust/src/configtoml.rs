//! TOML-subset parser for run configuration files.
//!
//! The launcher (`scalestudy train --config run.toml`) and study binaries
//! read configs in a TOML subset: `[section.subsection]` tables,
//! `key = value` pairs with string/int/float/bool/array values, and `#`
//! comments.  Values are materialized into the [`crate::json::Json`] tree
//! so downstream code has one value type for both formats.

use crate::json::Json;
use std::collections::BTreeMap;

/// Error with line number.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse TOML text into a JSON object tree.
pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let errf = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };

        if let Some(section) = line.strip_prefix('[') {
            let section = section
                .strip_suffix(']')
                .ok_or_else(|| errf("unterminated section header"))?;
            if section.is_empty() {
                return Err(errf("empty section name"));
            }
            current_path = section.split('.').map(|s| s.trim().to_string()).collect();
            if current_path.iter().any(|s| s.is_empty()) {
                return Err(errf("empty section path component"));
            }
            // ensure the table exists
            ensure_table(&mut root, &current_path).map_err(|m| errf(&m))?;
            continue;
        }

        let eq = line.find('=').ok_or_else(|| errf("expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(errf("empty key"));
        }
        let vtext = line[eq + 1..].trim();
        let value = parse_value(vtext).map_err(|m| errf(&m))?;

        let table = ensure_table(&mut root, &current_path).map_err(|m| errf(&m))?;
        if table.insert(key.to_string(), value).is_some() {
            return Err(errf(&format!("duplicate key '{key}'")));
        }
    }
    Ok(Json::Obj(root))
}

/// Parse a TOML file into a JSON object tree.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Ok(parse(&text)?)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>, String> {
    let mut cur = root;
    for key in path {
        let entry = cur
            .entry(key.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        match entry {
            Json::Obj(m) => cur = m,
            _ => return Err(format!("'{key}' is not a table")),
        }
    }
    Ok(cur)
}

fn parse_value(v: &str) -> Result<Json, String> {
    if v.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = v.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Json::Str(unescape(body)?));
    }
    if v == "true" {
        return Ok(Json::Bool(true));
    }
    if v == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(body) = v.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(body) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    // number (allow underscores as digit separators, TOML-style)
    let clean: String = v.chars().filter(|&c| c != '_').collect();
    clean
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("cannot parse value '{v}'"))
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape '\\{:?}'", other)),
        }
    }
    Ok(out)
}

/// Split on commas not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_config() {
        let toml = r#"
# run config
seed = 42
name = "mt5-xxl sweep"   # inline comment

[cluster]
nodes = 8
gpus_per_node = 8
ib_gbps = 200.0

[train.optimizer]
kind = "adamw"
lr = 1e-4
betas = [0.9, 0.999]
fused = true
"#;
        let j = parse(toml).unwrap();
        assert_eq!(j.get("seed").as_i64(), Some(42));
        assert_eq!(j.get("name").as_str(), Some("mt5-xxl sweep"));
        assert_eq!(j.path(&["cluster", "nodes"]).as_i64(), Some(8));
        assert_eq!(j.path(&["train", "optimizer", "lr"]).as_f64(), Some(1e-4));
        assert_eq!(
            j.path(&["train", "optimizer", "betas"]).as_arr().unwrap().len(),
            2
        );
        assert_eq!(j.path(&["train", "optimizer", "fused"]).as_bool(), Some(true));
    }

    #[test]
    fn nested_arrays_and_underscores() {
        let j = parse("xs = [[1, 2], [3, 4]]\nbig = 1_000_000").unwrap();
        assert_eq!(j.get("big").as_i64(), Some(1_000_000));
        assert_eq!(j.get("xs").as_arr().unwrap()[1].as_arr().unwrap()[0].as_i64(), Some(3));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let j = parse("s = \"a # b\"").unwrap();
        assert_eq!(j.get("s").as_str(), Some("a # b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbad line").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[unterminated").is_err());
        assert!(parse("dup = 1\ndup = 2").is_err());
    }
}
