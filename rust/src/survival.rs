//! Seeded trace-replay survival engine (PR 10, tentpole part 3).
//!
//! The closed-form goodput model in [`crate::resilience`] prices a plan's
//! failure exposure analytically: optimal checkpoint interval, expected
//! effective seconds per useful step.  This module is the discrete-event
//! counterpart — it samples concrete failure traces from the cluster's
//! [`crate::hardware::BlastDomain`] topology and replays the plan's
//! step / checkpoint / restore schedule against each trace, so the
//! analytical expectation can be validated against a Monte-Carlo
//! confidence band (the validation the closed form never had), and so the
//! *distribution* (p50/p99 useful-step rate, work lost, elastic replans)
//! becomes visible rather than just the mean.
//!
//! Determinism contract: the root RNG is split per trace index through
//! [`Sweep::map_seeded`], so the report is bit-identical at any worker
//! count and across CLI / serve front-ends for the same seed.
//!
//! Replay semantics (chosen to be first-order consistent with the
//! analytical model so the confidence-band test is meaningful):
//!
//! * A *period* is `m` useful steps followed by the policy's critical-path
//!   checkpoint stall: `m·step + stall0 + max(0, drain − m·budget)` — the
//!   exact `W` the interval optimizer minimises over.
//! * Failure inter-arrivals are exponential at the topology's total rate
//!   `Σ instances/MTBF`; a failure mid-period loses all work since the
//!   last complete checkpoint, then pays `restore + restart_overhead`.
//! * Failures during recovery are not stacked (memoryless resample after
//!   restore), matching the first-order analytical recovery term.
//! * Elastic mode makes failures *permanent*: the blast level that fired
//!   is sampled proportionally to its rate, the domain's members leave
//!   the cluster, and when the survivor count drops below the running
//!   plan's node count the trace re-plans from a precomputed per-node-
//!   count ladder (Goodput-objective winners); an infeasible survivor
//!   count exhausts the trace.

use crate::hardware::ClusterSpec;
use crate::model::ModelCfg;
use crate::planner::PlanSpace;
use crate::resilience::{plan_resilient, FailureModel};
use crate::sim::{TrainSetup, Workload};
use crate::sweep::{SimCache, Sweep};
use crate::timeline::checkpoint_drain_budget;
use crate::util::rng::Rng;

/// Knobs for one survival run.  Shared verbatim by the `survive` CLI
/// subcommand and the serve query so both front-ends stay bit-identical.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SurvivalSpec {
    /// Root seed; trace `i` replays with `Rng::new(seed).split(i)`.
    pub seed: u64,
    /// Number of independent traces (clamped to at least 1).
    pub traces: usize,
    /// Useful steps each trace must complete (clamped to at least 1).
    pub horizon_steps: usize,
    /// Replay permanent failures with elastic shrink + replan instead of
    /// in-place restore on a fixed cluster.
    pub elastic: bool,
}

impl Default for SurvivalSpec {
    fn default() -> SurvivalSpec {
        SurvivalSpec { seed: 0, traces: 256, horizon_steps: 4096, elastic: false }
    }
}

/// Distribution summary over all replayed traces.
#[derive(Clone, Debug)]
pub struct SurvivalReport {
    pub traces: usize,
    pub horizon_steps: usize,
    pub elastic: bool,
    /// Useful steps per wall-clock second from the closed form
    /// (`1 / effective_seconds_per_step` of the unshrunk plan).
    pub analytic_rate: f64,
    /// Mean useful-step rate over traces.
    pub mean_rate: f64,
    /// Median useful-step rate.
    pub p50_rate: f64,
    /// Rate achieved by the 99th-percentile-WORST trace (ascending 1%
    /// quantile): 99% of traces do at least this well.
    pub p99_rate: f64,
    /// Standard error of `mean_rate` (population σ / √traces) — the
    /// Monte-Carlo confidence band the analytic rate is tested against.
    pub sem_rate: f64,
    pub mean_failures: f64,
    pub mean_replans: f64,
    /// Mean seconds of work lost to rollbacks per trace.
    pub mean_lost_s: f64,
    /// Traces that ran out of feasible survivors (elastic mode only).
    pub exhausted_traces: usize,
}

/// The survival view of one planner winner plus its replayed report.
#[derive(Clone, Debug)]
pub struct SurvivalOutcome {
    pub label: String,
    pub nodes: usize,
    pub seconds_per_step: f64,
    pub interval_steps: usize,
    pub report: SurvivalReport,
}

/// Everything the replay loop needs about one plan at one node count.
#[derive(Clone, Debug)]
struct Rung {
    nodes: usize,
    step_s: f64,
    interval_steps: usize,
    /// `m·step + stall0 + spill` — wall seconds per complete period.
    period_s: f64,
    /// `restore + restart_overhead` charged per failure.
    recovery_s: f64,
    lambda_per_s: f64,
    /// `(rate, blast size)` per topology level, summing to `lambda_per_s`.
    levels: Vec<(f64, usize)>,
}

fn rung_for(setup: &TrainSetup, step_s: f64, fm: &FailureModel) -> Rung {
    let nodes = setup.cluster.total_nodes();
    let lambda = fm.lambda_for(&setup.cluster);
    if !(lambda > 0.0) || !(step_s.is_finite() && step_s > 0.0) {
        // Failure-free (or unpriceable) plans never checkpoint: one step
        // per period, no stall, no recovery.
        return Rung {
            nodes,
            step_s,
            interval_steps: 1,
            period_s: step_s,
            recovery_s: 0.0,
            lambda_per_s: 0.0,
            levels: Vec::new(),
        };
    }
    let g = fm.goodput(setup, step_s);
    let ckpt = fm.checkpoint_cost(setup);
    let m = g.interval_steps.max(1);
    let spill = (ckpt.drain_s - m as f64 * checkpoint_drain_budget(step_s)).max(0.0);
    Rung {
        nodes,
        step_s,
        interval_steps: m,
        period_s: m as f64 * step_s + ckpt.write_s + spill,
        recovery_s: ckpt.restore_s + fm.restart_overhead_s,
        lambda_per_s: lambda,
        levels: fm
            .topology(&setup.cluster)
            .levels
            .iter()
            .map(|l| (l.lambda_per_s, l.size))
            .collect(),
    }
}

/// Per-trace tallies folded into the report.
#[derive(Clone, Copy, Debug)]
struct TraceStats {
    rate: f64,
    failures: u64,
    replans: u64,
    lost_s: f64,
    exhausted: bool,
}

fn exp_draw(rng: &mut Rng, lambda: f64) -> f64 {
    // f64() < 1.0 strictly, so the log argument is never 0.
    -(1.0 - rng.f64()).ln() / lambda
}

/// Which blast level fired, proportional to per-level rates; returns the
/// number of nodes the failure takes out.
fn pick_blast(rng: &mut Rng, levels: &[(f64, usize)], total: f64) -> usize {
    let mut u = rng.f64() * total;
    for &(lam, size) in levels {
        if u < lam {
            return size;
        }
        u -= lam;
    }
    levels.last().map(|&(_, s)| s).unwrap_or(1)
}

/// Replay one trace on a fixed cluster (failures restore in place).
fn replay_static(rng: &mut Rng, rung: &Rung, horizon_steps: usize) -> TraceStats {
    let horizon = horizon_steps as u64;
    if !(rung.lambda_per_s > 0.0) {
        let m = rung.interval_steps as u64;
        let periods = (horizon + m - 1) / m;
        let wall = periods as f64 * rung.period_s;
        let useful = periods * rung.interval_steps as u64;
        let rate = if wall > 0.0 { useful as f64 / wall } else { 0.0 };
        return TraceStats { rate, failures: 0, replans: 0, lost_s: 0.0, exhausted: false };
    }
    let mut useful = 0u64;
    let mut wall = 0.0;
    let mut failures = 0u64;
    let mut lost = 0.0;
    let mut to_fail = exp_draw(rng, rung.lambda_per_s);
    while useful < horizon {
        if to_fail >= rung.period_s {
            // The period completes and its checkpoint commits.
            to_fail -= rung.period_s;
            wall += rung.period_s;
            useful += rung.interval_steps as u64;
        } else {
            // Mid-period failure: everything since the last checkpoint
            // is lost, then the trace pays the recovery bill.
            failures += 1;
            lost += to_fail;
            wall += to_fail + rung.recovery_s;
            to_fail = exp_draw(rng, rung.lambda_per_s);
        }
    }
    TraceStats { rate: useful as f64 / wall, failures, replans: 0, lost_s: lost, exhausted: false }
}

/// Replay one trace with permanent failures: each event removes a blast
/// domain's members, and the trace re-plans from `ladder[survivors]`.
fn replay_elastic(
    rng: &mut Rng,
    ladder: &[Option<Rung>],
    start_nodes: usize,
    horizon_steps: usize,
) -> TraceStats {
    let horizon = horizon_steps as u64;
    let mut avail = start_nodes;
    let mut useful = 0u64;
    let mut wall = 0.0;
    let mut failures = 0u64;
    let mut replans = 0u64;
    let mut lost = 0.0;
    let mut exhausted = false;
    'run: while useful < horizon {
        let Some(rung) = ladder.get(avail).and_then(|r| r.as_ref()) else {
            exhausted = true;
            break;
        };
        if !(rung.lambda_per_s > 0.0) {
            let left = horizon - useful;
            let m = rung.interval_steps as u64;
            let periods = (left + m - 1) / m;
            wall += periods as f64 * rung.period_s;
            useful += periods * rung.interval_steps as u64;
            break;
        }
        let mut to_fail = exp_draw(rng, rung.lambda_per_s);
        while useful < horizon {
            if to_fail >= rung.period_s {
                to_fail -= rung.period_s;
                wall += rung.period_s;
                useful += rung.interval_steps as u64;
            } else {
                failures += 1;
                lost += to_fail;
                wall += to_fail + rung.recovery_s;
                let dead = pick_blast(rng, &rung.levels, rung.lambda_per_s).min(avail);
                avail -= dead;
                if avail == 0 {
                    exhausted = true;
                    break 'run;
                }
                if avail < rung.nodes {
                    // The survivors no longer fit the running plan — the
                    // next loop iteration re-plans from the ladder.
                    replans += 1;
                }
                continue 'run;
            }
        }
        break;
    }
    let rate = if wall > 0.0 { useful as f64 / wall } else { 0.0 };
    TraceStats { rate, failures, replans, lost_s: lost, exhausted }
}

fn aggregate(stats: &[TraceStats], analytic_rate: f64, spec: &SurvivalSpec) -> SurvivalReport {
    let n = stats.len().max(1) as f64;
    let mean_rate = stats.iter().map(|t| t.rate).sum::<f64>() / n;
    let var = stats.iter().map(|t| (t.rate - mean_rate) * (t.rate - mean_rate)).sum::<f64>() / n;
    let mut rates: Vec<f64> = stats.iter().map(|t| t.rate).collect();
    rates.sort_by(|a, b| a.total_cmp(b));
    let quant = |q: f64| -> f64 {
        if rates.is_empty() {
            return 0.0;
        }
        rates[((rates.len() - 1) as f64 * q).round() as usize]
    };
    SurvivalReport {
        traces: stats.len(),
        horizon_steps: spec.horizon_steps.max(1),
        elastic: spec.elastic,
        analytic_rate,
        mean_rate,
        p50_rate: quant(0.5),
        p99_rate: quant(0.01),
        sem_rate: (var / n).sqrt(),
        mean_failures: stats.iter().map(|t| t.failures as f64).sum::<f64>() / n,
        mean_replans: stats.iter().map(|t| t.replans as f64).sum::<f64>() / n,
        mean_lost_s: stats.iter().map(|t| t.lost_s).sum::<f64>() / n,
        exhausted_traces: stats.iter().filter(|t| t.exhausted).count(),
    }
}

fn analytic_rate_for(setup: &TrainSetup, step_s: f64, fm: &FailureModel) -> f64 {
    if !(step_s.is_finite() && step_s > 0.0) {
        return 0.0;
    }
    if fm.enabled_for(&setup.cluster) {
        let eff = fm.goodput(setup, step_s).effective_seconds_per_step;
        if eff > 0.0 {
            1.0 / eff
        } else {
            0.0
        }
    } else {
        1.0 / step_s
    }
}

/// Replay an already-priced setup on a fixed cluster (no planner, no
/// elastic shrink).  This is the primitive the MC-vs-analytic property
/// test exercises per zoo model.
pub fn replay_setup(
    setup: &TrainSetup,
    step_s: f64,
    fm: &FailureModel,
    spec: &SurvivalSpec,
    sweep: &Sweep,
) -> SurvivalReport {
    let rung = rung_for(setup, step_s, fm);
    let horizon = spec.horizon_steps.max(1);
    let idxs: Vec<u64> = (0..spec.traces.max(1) as u64).collect();
    let stats =
        sweep.map_seeded(spec.seed, &idxs, |_, _, rng| replay_static(rng, &rung, horizon));
    aggregate(&stats, analytic_rate_for(setup, step_s, fm), spec)
}

/// Plan under the failure model, then replay the winner.  In elastic mode
/// a Goodput-winner ladder is precomputed for every survivor count so the
/// replay loop never plans inside a trace (keeps traces cheap AND
/// deterministic regardless of trace order).
pub fn survive(
    model: &ModelCfg,
    cluster: &ClusterSpec,
    workload: &Workload,
    space: &PlanSpace,
    fm: &FailureModel,
    spec: &SurvivalSpec,
    sweep: &Sweep,
    cache: &SimCache,
) -> Option<SurvivalOutcome> {
    let planned = plan_resilient(model, cluster, workload, space, fm, sweep, cache);
    let best = planned
        .best
        .as_ref()
        .filter(|b| b.point.seconds_per_step().is_finite())?;
    let step_s = best.point.seconds_per_step();
    let n0 = best.point.setup.cluster.total_nodes();
    let horizon = spec.horizon_steps.max(1);
    let idxs: Vec<u64> = (0..spec.traces.max(1) as u64).collect();
    let stats = if spec.elastic {
        let mut ladder: Vec<Option<Rung>> = vec![None; n0 + 1];
        ladder[n0] = Some(rung_for(&best.point.setup, step_s, fm));
        for k in 1..n0 {
            let sub = cluster.take_nodes(k);
            ladder[k] = plan_resilient(model, &sub, workload, space, fm, sweep, cache)
                .best
                .filter(|b| b.point.seconds_per_step().is_finite())
                .map(|b| rung_for(&b.point.setup, b.point.seconds_per_step(), fm));
        }
        sweep.map_seeded(spec.seed, &idxs, |_, _, rng| {
            replay_elastic(rng, &ladder, n0, horizon)
        })
    } else {
        let rung = rung_for(&best.point.setup, step_s, fm);
        sweep.map_seeded(spec.seed, &idxs, |_, _, rng| replay_static(rng, &rung, horizon))
    };
    let report = aggregate(&stats, analytic_rate_for(&best.point.setup, step_s, fm), spec);
    Some(SurvivalOutcome {
        label: best.point.label(),
        nodes: n0,
        seconds_per_step: step_s,
        interval_steps: if fm.enabled_for(&best.point.setup.cluster) {
            best.goodput.interval_steps
        } else {
            0
        },
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::BlastDomain;
    use crate::model;
    use crate::resilience::CheckpointPolicy;
    use crate::sim::simulate_step;
    use crate::zero::{OptimizerKind, ZeroStage};

    fn small_space() -> PlanSpace {
        PlanSpace {
            optimizers: vec![OptimizerKind::AdamW, OptimizerKind::Adafactor],
            micro_batch_caps: vec![0, 8],
            schedules: vec![crate::parallel::PipeSchedule::OneFOneB],
            nodes: vec![1, 2, 4],
            max_tp: 4,
            max_pp: 2,
            max_sp: 1,
            max_ep: 1,
            ..PlanSpace::default()
        }
    }

    /// The acceptance property: for EVERY zoo model, the closed-form
    /// goodput rate lands inside the seeded Monte-Carlo confidence band
    /// of the trace-replay engine.
    #[test]
    fn analytic_rate_inside_mc_confidence_band_for_every_zoo_model() {
        let sweep = Sweep::serial();
        for m in model::mt5_zoo() {
            let name = m.name.clone();
            let setup = TrainSetup::dp_pod(m, 4, ZeroStage::Stage2);
            let step_s = simulate_step(&setup).seconds_per_step();
            if !step_s.is_finite() {
                continue;
            }
            let fm = FailureModel::with_mtbf(200.0);
            // Horizon of ~50 checkpoint periods keeps traces long enough
            // to see failures but cheap enough to run the whole zoo.
            let interval = fm.goodput(&setup, step_s).interval_steps.max(1);
            let spec = SurvivalSpec {
                seed: 7,
                traces: 200,
                horizon_steps: interval * 50,
                elastic: false,
            };
            let rep = replay_setup(&setup, step_s, &fm, &spec, &sweep);
            assert!(rep.mean_rate > 0.0, "{name}: degenerate MC rate");
            // 4 standard errors plus a small relative floor for the
            // second-order terms the closed form drops by design.
            let tol = 4.0 * rep.sem_rate + 2e-3 * rep.analytic_rate;
            assert!(
                (rep.mean_rate - rep.analytic_rate).abs() <= tol,
                "{name}: analytic {} vs MC {} ± {} (tol {})",
                rep.analytic_rate,
                rep.mean_rate,
                rep.sem_rate,
                tol
            );
            // The worst-1% trace can never beat the median.
            assert!(rep.p99_rate <= rep.p50_rate + 1e-12, "{name}: p99 > p50");
        }
    }

    #[test]
    fn traces_bit_identical_at_any_worker_count() {
        let m = model::by_name("mt5-xl").unwrap();
        let setup = TrainSetup::dp_pod(m, 4, ZeroStage::Stage2);
        let step_s = simulate_step(&setup).seconds_per_step();
        assert!(step_s.is_finite());
        let mut fm = FailureModel::with_mtbf(1.0);
        fm.policy = CheckpointPolicy::Async { snapshot_s: 2.0, drain_bw: 2.0e9 };
        let spec = SurvivalSpec { seed: 99, traces: 64, horizon_steps: 512, elastic: false };
        let serial = replay_setup(&setup, step_s, &fm, &spec, &Sweep::serial());
        for workers in [2usize, 5] {
            let par = replay_setup(&setup, step_s, &fm, &spec, &Sweep::new(workers));
            assert_eq!(serial.mean_rate.to_bits(), par.mean_rate.to_bits());
            assert_eq!(serial.p50_rate.to_bits(), par.p50_rate.to_bits());
            assert_eq!(serial.p99_rate.to_bits(), par.p99_rate.to_bits());
            assert_eq!(serial.sem_rate.to_bits(), par.sem_rate.to_bits());
            assert_eq!(serial.mean_lost_s.to_bits(), par.mean_lost_s.to_bits());
        }
        // Same seed reproduces; a different seed draws different traces.
        let again = replay_setup(&setup, step_s, &fm, &spec, &Sweep::serial());
        assert_eq!(serial.mean_rate.to_bits(), again.mean_rate.to_bits());
        let other = replay_setup(
            &setup,
            step_s,
            &fm,
            &SurvivalSpec { seed: 100, ..spec },
            &Sweep::serial(),
        );
        assert_ne!(
            serial.mean_rate.to_bits(),
            other.mean_rate.to_bits(),
            "different seeds must draw different traces"
        );
    }

    #[test]
    fn disabled_failure_model_replays_failure_free() {
        let m = model::by_name("mt5-large").unwrap();
        let setup = TrainSetup::dp_pod(m, 2, ZeroStage::Stage2);
        let step_s = simulate_step(&setup).seconds_per_step();
        assert!(step_s.is_finite());
        let spec = SurvivalSpec { seed: 1, traces: 16, horizon_steps: 100, elastic: false };
        let rep = replay_setup(&setup, step_s, &FailureModel::disabled(), &spec, &Sweep::serial());
        let ideal = 1.0 / step_s;
        assert_eq!(rep.mean_rate.to_bits(), ideal.to_bits());
        assert_eq!(rep.p50_rate.to_bits(), ideal.to_bits());
        assert_eq!(rep.p99_rate.to_bits(), ideal.to_bits());
        assert_eq!(rep.analytic_rate.to_bits(), ideal.to_bits());
        assert_eq!(rep.sem_rate, 0.0);
        assert_eq!(rep.mean_failures, 0.0);
        assert_eq!(rep.mean_lost_s, 0.0);
    }

    /// Elastic replay on a harsh correlated topology: failures happen,
    /// domain deaths force replans, and every trace still reports a
    /// finite rate (or a counted exhaustion).
    #[test]
    fn elastic_replay_shrinks_replans_and_survives() {
        let m = model::by_name("mt5-large").unwrap();
        let mut cluster = ClusterSpec::lps_pod(4);
        cluster.domains = vec![BlastDomain {
            name: "switch".into(),
            size: 2,
            mtbf_hours: 25.0,
        }];
        // MTBF mild enough that the 4-node plan still wins (so elastic
        // shrink has room to replan downward), harsh enough that a
        // 100k-step horizon sees failures in essentially every run.
        let mut fm = FailureModel::with_mtbf(50.0);
        fm.restart_overhead_s = 60.0;
        let w = Workload::table1();
        let space = small_space();
        let cache = SimCache::new();
        let sweep = Sweep::serial();
        let spec = SurvivalSpec { seed: 3, traces: 24, horizon_steps: 100_000, elastic: true };
        let out = survive(&m, &cluster, &w, &space, &fm, &spec, &sweep, &cache)
            .expect("plan must exist");
        assert!(out.nodes > 0 && out.seconds_per_step.is_finite());
        let rep = &out.report;
        assert!(rep.elastic);
        assert!(rep.mean_failures > 0.0, "harsh MTBF must produce failures");
        assert!(rep.mean_replans > 0.0, "node deaths must force elastic replans");
        assert!(rep.mean_lost_s > 0.0);
        assert!(rep.exhausted_traces <= rep.traces);
        assert!(rep.mean_rate.is_finite() && rep.mean_rate >= 0.0);
        // Deterministic: the same spec replays bit-identically even
        // through the planner + ladder path.
        let again = survive(&m, &cluster, &w, &space, &fm, &spec, &sweep, &cache).unwrap();
        assert_eq!(rep.mean_rate.to_bits(), again.report.mean_rate.to_bits());
        assert_eq!(rep.mean_replans, again.report.mean_replans);
        assert_eq!(rep.exhausted_traces, again.report.exhausted_traces);
        // Non-elastic on the same problem keeps the cluster whole.
        let fixed = survive(
            &m,
            &cluster,
            &w,
            &space,
            &fm,
            &SurvivalSpec { elastic: false, ..spec },
            &sweep,
            &cache,
        )
        .unwrap();
        assert_eq!(fixed.report.mean_replans, 0.0);
        assert_eq!(fixed.report.exhausted_traces, 0);
    }

    /// More traces tighten the confidence band (SEM shrinks roughly as
    /// 1/√N) — a sanity check that the aggregation is actually computing
    /// a standard error and not a population σ.
    #[test]
    fn sem_shrinks_with_trace_count() {
        let m = model::by_name("mt5-base").unwrap();
        let setup = TrainSetup::dp_pod(m, 4, ZeroStage::Stage2);
        let step_s = simulate_step(&setup).seconds_per_step();
        assert!(step_s.is_finite());
        let fm = FailureModel::with_mtbf(0.5);
        let sweep = Sweep::serial();
        let small = replay_setup(
            &setup,
            step_s,
            &fm,
            &SurvivalSpec { seed: 11, traces: 32, horizon_steps: 2048, elastic: false },
            &sweep,
        );
        let big = replay_setup(
            &setup,
            step_s,
            &fm,
            &SurvivalSpec { seed: 11, traces: 512, horizon_steps: 2048, elastic: false },
            &sweep,
        );
        assert!(small.sem_rate > 0.0, "harsh MTBF must spread the traces");
        assert!(
            big.sem_rate < small.sem_rate,
            "16x the traces must tighten the band: {} vs {}",
            big.sem_rate,
            small.sem_rate
        );
    }
}
