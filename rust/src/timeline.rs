//! Event-driven pipeline timeline engine — the per-micro-batch
//! discrete-event simulator that replaced [`crate::sim`]'s scalar
//! bubble/overlap heuristics.
//!
//! ## Task graph
//!
//! One training step is a DAG of `(stage, micro-batch, chunk)` compute
//! tasks.  Each physical pipeline stage executes a **static per-rank op
//! sequence** — the schedules' textbook definitions:
//!
//! * **GPipe**: all forwards, then all backwards (per-stage flush);
//! * **1F1B**: `p − 1 − s` warmup forwards, then strict 1-forward/
//!   1-backward alternation, then cooldown backwards;
//! * **Interleaved-1F1B**: each rank hosts
//!   [`INTERLEAVE_DEGREE`](crate::parallel::INTERLEAVE_DEGREE) virtual
//!   stages (model chunks); micro-batches traverse chunk-major groups of
//!   `p` (Megatron's traversal), warmup is `2(p−1−s) + (v−1)p` chunk
//!   forwards.  Megatron requires `m % p == 0`; the engine instead pads
//!   the last group with zero-duration, zero-delay **ghost micro-batches**
//!   so the same static order is deadlock-free for any `m` (ghosts
//!   enqueue no communication and do not count toward the in-flight
//!   peak).
//!
//! Cross-stage edges (activations forward, gradients backward) carry the
//! p2p transfer time as a **dependency delay**: the receiving stage idles
//! while the transfer is in flight, so pipeline communication surfaces as
//! measured bubble rather than a scalar "exposed" guess.
//!
//! ## Stream model
//!
//! Each stage owns two streams.  The **compute stream** runs the task
//! sequence; blocking collectives (TP all-reduces, ZeRO-3 forward
//! gathers, the forward halves of SP ring and MoE all-to-all) extend the
//! task durations.  The **comm stream** carries the overlappable classes
//! — ZeRO bucketed gradient reduction, the ZeRO-3 backward re-gather
//! (when prefetch is on), the backward halves of SP ring and MoE
//! all-to-all, and the sequence-parallel replicated-gradient all-reduce —
//! as a fluid backlog that drains at [`OVERLAP_EFFICIENCY`] of each
//! backward-compute window (DeepSpeed's bucketing overlaps backward, at
//! the same efficiency the closed form assumed) and at full rate during
//! idle gaps; whatever is left at the end of the stage's sequence extends
//! its finish time as exposed communication.  `overlap_comm = false`
//! **serializes the streams**: every comm-stream second is inlined into
//! the issuing backward task and nothing hides.
//!
//! ## Degeneracy guarantees
//!
//! For `pp == 1` the task graph is a serial chain with no idle gaps, so
//! the engine collapses to the closed form exactly:
//! `exposed = blocking + max(0, overlappable − 0.85·backward)` (or the
//! full sum with overlap off) — [`crate::sim::simulate_step`] evaluates
//! that case through the identical shared expressions, and the unit
//! tests assert bit-equality against the scalar reference.  Elsewhere the
//! engine stays within a property-tested band of the reference.

use crate::parallel::{PipeSchedule, INTERLEAVE_DEGREE};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Fraction of a backward-compute window the comm stream can use
/// (DeepSpeed bucketing leaves some SM/copy-engine contention).
pub const OVERLAP_EFFICIENCY: f64 = 0.85;

/// Per-step pipeline inputs, all in seconds per rank.
#[derive(Clone, Copy, Debug)]
pub struct PipeInputs {
    pub sched: PipeSchedule,
    /// Physical pipeline stages.  `pp == 1` degenerates to the closed
    /// form exactly ([`crate::sim`] evaluates that case analytically and
    /// the tests assert the engine agrees).
    pub pp: usize,
    /// Micro-batches per rank per step.
    pub num_micro: usize,
    /// Whole-step forward compute per stage.
    pub fwd_total: f64,
    /// Whole-step backward compute per stage.
    pub bwd_total: f64,
    /// Blocking comm inside each micro-batch's forward task (per-stage
    /// layer share).
    pub blocking_fwd_micro: f64,
    /// Blocking comm inside each micro-batch's backward task.
    pub blocking_bwd_micro: f64,
    /// Comm-stream seconds enqueued at each micro-batch's backward.
    pub ovl_micro: f64,
    /// Comm-stream seconds streamed uniformly across the backward phase
    /// (per-step gradient reduction).
    pub ovl_step: f64,
    /// p2p seconds per stage-boundary crossing.
    pub hop: f64,
    /// Overlap the comm stream with compute; `false` serializes.
    pub overlap: bool,
}

/// The engine's per-step outcome, decomposed on the critical stage.
#[derive(Clone, Copy, Debug)]
pub struct PipeOutcome {
    /// Wall time of the step's compute+comm window (excl. optimizer and
    /// input stall, which the caller adds).
    pub makespan: f64,
    /// Comm-stream seconds left exposed on the critical stage (all of
    /// them when overlap is off).
    pub exposed_grad: f64,
    /// Blocking comm on the critical stage.
    pub exposed_blocking: f64,
    /// Idle seconds on the critical stage (the measured bubble).
    pub bubble: f64,
    /// Stage index that set the makespan.
    pub critical_stage: usize,
    /// Largest number of real micro-batches simultaneously in flight on
    /// any stage (≤ [`crate::parallel::live_microbatches`]).
    pub peak_inflight: usize,
}

/// Megatron's interleaved traversal: groups of `p` micro-batches,
/// chunk-major inside a group.  `nm_pad` must be a multiple of `p`.
fn chunk_order(p: usize, nm_pad: usize, v: usize, reverse_chunks: bool) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(nm_pad * v);
    for g in 0..nm_pad / p {
        for cf in 0..v {
            let c = if reverse_chunks { v - 1 - cf } else { cf };
            for slot in 0..p {
                out.push((g * p + slot, c));
            }
        }
    }
    out
}

/// Static op sequence of physical stage `s`: `(is_bwd, micro, chunk)`.
/// Interleaved sequences include ghost micros `>= nm` (see module docs).
fn stage_sequence(
    sched: PipeSchedule,
    p: usize,
    s: usize,
    nm: usize,
    v: usize,
) -> Vec<(bool, usize, usize)> {
    let (fwd, bwd) = if sched == PipeSchedule::Interleaved1F1B {
        let nm_pad = ((nm + p - 1) / p) * p;
        (chunk_order(p, nm_pad, v, false), chunk_order(p, nm_pad, v, true))
    } else {
        (
            (0..nm).map(|m| (m, 0usize)).collect::<Vec<_>>(),
            (0..nm).map(|m| (m, 0usize)).collect::<Vec<_>>(),
        )
    };
    let total = fwd.len();
    if sched == PipeSchedule::GPipe {
        let mut seq: Vec<(bool, usize, usize)> =
            fwd.iter().map(|&(m, c)| (false, m, c)).collect();
        seq.extend(bwd.iter().map(|&(m, c)| (true, m, c)));
        return seq;
    }
    let warmup = match sched {
        PipeSchedule::OneFOneB => (p - 1 - s).min(total),
        _ => {
            let nm_pad = fwd.len() / v;
            if nm_pad == p {
                total
            } else {
                ((p - 1 - s) * 2 + (v - 1) * p).min(total)
            }
        }
    };
    let mut seq = Vec::with_capacity(2 * total);
    let (mut fc, mut bc) = (0usize, 0usize);
    while fc < warmup {
        let (m, c) = fwd[fc];
        seq.push((false, m, c));
        fc += 1;
    }
    while fc < total {
        let (m, c) = fwd[fc];
        seq.push((false, m, c));
        fc += 1;
        let (m, c) = bwd[bc];
        seq.push((true, m, c));
        bc += 1;
    }
    while bc < total {
        let (m, c) = bwd[bc];
        seq.push((true, m, c));
        bc += 1;
    }
    seq
}

/// Heap event, min-ordered by (time, seq) — `seq` makes ties (and the
/// whole simulation) deterministic.
#[derive(Clone, Copy, Debug)]
struct Event {
    time: f64,
    seq: u64,
    /// `usize::MAX` marks a stage wake-up; otherwise a completed task id.
    task: usize,
    stage: usize,
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Event) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Simulate one step's pipeline.  Panics on an internal scheduling
/// inconsistency (a structural deadlock), which the static sequences are
/// property-tested never to produce for any `(schedule, pp, num_micro)`.
pub fn simulate_pipeline(inp: &PipeInputs) -> PipeOutcome {
    let p = inp.pp.max(1);
    let nm = inp.num_micro.max(1);
    let v = if inp.sched == PipeSchedule::Interleaved1F1B { INTERLEAVE_DEGREE } else { 1 };
    let nm_pad = if inp.sched == PipeSchedule::Interleaved1F1B {
        ((nm + p - 1) / p) * p
    } else {
        nm
    };
    let vf = v as f64;
    let nmf = nm as f64;
    let fwd_chunk = inp.fwd_total / nmf / vf;
    let bwd_chunk = inp.bwd_total / nmf / vf;
    let per_bwd_work = inp.ovl_micro / vf + inp.ovl_step / (nmf * vf);
    let fwd_dur = fwd_chunk + inp.blocking_fwd_micro / vf;
    let mut bwd_dur = bwd_chunk + inp.blocking_bwd_micro / vf;
    if !inp.overlap {
        bwd_dur += per_bwd_work; // serialize the streams
    }

    let seqs: Vec<Vec<(bool, usize, usize)>> =
        (0..p).map(|s| stage_sequence(inp.sched, p, s, nm, v)).collect();

    // dense task ids: ((bwd·p + stage)·nm_pad + micro)·v + chunk
    let idx = |bwd: bool, st: usize, m: usize, c: usize| -> usize {
        (((bwd as usize) * p + st) * nm_pad + m) * v + c
    };
    let n_ids = 2 * p * nm_pad * v;
    let mut ndeps = vec![0u8; n_ids];
    let mut waiters: Vec<Vec<usize>> = vec![Vec::new(); n_ids];
    for (st, seq) in seqs.iter().enumerate() {
        for &(bwd, m, c) in seq {
            let t = idx(bwd, st, m, c);
            let mut add = |d: usize| {
                ndeps[t] += 1;
                waiters[d].push(t);
            };
            if !bwd {
                if st > 0 {
                    add(idx(false, st - 1, m, c));
                } else if c > 0 {
                    add(idx(false, p - 1, m, c - 1));
                }
            } else {
                add(idx(false, st, m, c));
                if st < p - 1 {
                    add(idx(true, st + 1, m, c));
                } else if c < v - 1 {
                    add(idx(true, 0, m, c + 1));
                }
            }
        }
    }

    let decode = |t: usize| -> (bool, usize, usize, usize) {
        let c = t % v;
        let m = (t / v) % nm_pad;
        let st = (t / v / nm_pad) % p;
        let bwd = t / v / nm_pad / p == 1;
        (bwd, st, m, c)
    };

    let mut ready_time = vec![0.0f64; n_ids];
    let mut ptr = vec![0usize; p];
    let mut busy = vec![false; p];
    let mut free_at = vec![0.0f64; p];
    let mut n_done = 0usize;
    let n_tasks: usize = seqs.iter().map(|s| s.len()).sum();
    let mut stage_last_end = vec![0.0f64; p];
    // (span, is_bwd, is_idle, bwd_compute_span) intervals per stage
    let mut intervals: Vec<Vec<(f64, bool, bool, f64)>> = vec![Vec::new(); p];
    let mut inflight = vec![0usize; p];
    let mut peak_inflight = 0usize;
    let mut fwd_started: Vec<Vec<bool>> = vec![vec![false; nm]; p];
    let mut bwd_done_count: Vec<Vec<usize>> = vec![vec![0; nm]; p];

    let mut heap: BinaryHeap<Event> = BinaryHeap::new();
    let mut evseq = 0u64;

    macro_rules! dispatch {
        ($st:expr, $now:expr) => {{
            let st = $st;
            let now: f64 = $now;
            if !busy[st] && ptr[st] < seqs[st].len() {
                let (bwd, m, c) = seqs[st][ptr[st]];
                let t = idx(bwd, st, m, c);
                if ndeps[t] == 0 {
                    let rt = ready_time[t];
                    if rt > now {
                        heap.push(Event { time: rt, seq: evseq, task: usize::MAX, stage: st });
                        evseq += 1;
                    } else {
                        let ghost = m >= nm;
                        let start = if free_at[st] > now { free_at[st] } else { now };
                        if !bwd && !ghost && !fwd_started[st][m] {
                            fwd_started[st][m] = true;
                            inflight[st] += 1;
                            peak_inflight = peak_inflight.max(inflight[st]);
                        }
                        busy[st] = true;
                        ptr[st] += 1;
                        let dur = if ghost {
                            0.0
                        } else if bwd {
                            bwd_dur
                        } else {
                            fwd_dur
                        };
                        let end = start + dur;
                        if !ghost {
                            if start > stage_last_end[st] {
                                intervals[st].push((
                                    start - stage_last_end[st],
                                    false,
                                    true,
                                    0.0,
                                ));
                            }
                            intervals[st].push((
                                dur,
                                bwd,
                                false,
                                if bwd { bwd_chunk } else { 0.0 },
                            ));
                            stage_last_end[st] = end;
                        }
                        free_at[st] = end;
                        heap.push(Event { time: end, seq: evseq, task: t, stage: st });
                        evseq += 1;
                    }
                }
            }
        }};
    }

    for st in 0..p {
        dispatch!(st, 0.0);
    }
    while let Some(ev) = heap.pop() {
        if ev.task == usize::MAX {
            dispatch!(ev.stage, ev.time);
            continue;
        }
        let (bwd, st, m, _c) = decode(ev.task);
        n_done += 1;
        busy[st] = false;
        if bwd && m < nm {
            bwd_done_count[st][m] += 1;
            if bwd_done_count[st][m] == v {
                inflight[st] -= 1;
            }
        }
        let hop = if m >= nm { 0.0 } else { inp.hop };
        for wi in 0..waiters[ev.task].len() {
            let w = waiters[ev.task][wi];
            ndeps[w] -= 1;
            let (_, wst, wm, _) = decode(w);
            // same-stage forward→backward edges carry no transfer
            let delay = if wst == st && wm == m { 0.0 } else { hop };
            let rt = ev.time + delay;
            if rt > ready_time[w] {
                ready_time[w] = rt;
            }
        }
        for st2 in 0..p {
            dispatch!(st2, ev.time);
        }
    }
    assert_eq!(
        n_done, n_tasks,
        "pipeline deadlock: {n_done}/{n_tasks} ({:?}, p={p}, m={nm})",
        inp.sched
    );

    // ---- fluid comm-stream drain per stage
    let mut makespan = f64::NEG_INFINITY;
    let mut crit = 0usize;
    let mut crit_backlog = 0.0f64;
    for st in 0..p {
        let mut backlog = 0.0f64;
        if inp.overlap {
            for &(span, is_bwd, is_idle, bspan) in &intervals[st] {
                if is_bwd {
                    let avail = backlog + per_bwd_work;
                    let drained = avail.min(OVERLAP_EFFICIENCY * bspan);
                    backlog = avail - drained;
                } else if is_idle {
                    backlog -= backlog.min(span);
                }
            }
        }
        let finish = stage_last_end[st] + backlog;
        if finish > makespan {
            makespan = finish;
            crit = st;
            crit_backlog = backlog;
        }
    }
    let compute_st = inp.fwd_total + inp.bwd_total;
    let blocking = (inp.blocking_fwd_micro + inp.blocking_bwd_micro) * nmf;
    let ovl_total = inp.ovl_micro * nmf + inp.ovl_step;
    let exposed_grad = if inp.overlap { crit_backlog } else { ovl_total };
    let idle = makespan - compute_st - blocking - exposed_grad;
    PipeOutcome {
        makespan,
        exposed_grad,
        exposed_blocking: blocking,
        bubble: idle.max(0.0),
        critical_stage: crit,
        peak_inflight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(sched: PipeSchedule, p: usize, m: usize) -> PipeOutcome {
        simulate_pipeline(&PipeInputs {
            sched,
            pp: p,
            num_micro: m,
            fwd_total: m as f64,
            bwd_total: m as f64,
            blocking_fwd_micro: 0.0,
            blocking_bwd_micro: 0.0,
            ovl_micro: 0.0,
            ovl_step: 0.0,
            hop: 0.0,
            overlap: true,
        })
    }

    /// The engine reproduces the textbook bubbles exactly on uniform
    /// tasks: GPipe/1F1B idle (p−1)(f+b), interleaved 1/v of that.
    #[test]
    fn bubbles_match_schedule_theory() {
        for (p, m) in [(4usize, 8usize), (4, 16), (8, 16), (2, 8)] {
            let ideal = 2.0 * m as f64;
            let theory = (p - 1) as f64 * 2.0;
            for sched in [PipeSchedule::OneFOneB, PipeSchedule::GPipe] {
                let o = run(sched, p, m);
                assert!(
                    (o.makespan - (ideal + theory)).abs() < 1e-9,
                    "{sched:?} p={p} m={m}: makespan {}",
                    o.makespan
                );
                assert!((o.bubble - theory).abs() < 1e-9);
            }
            let o = run(PipeSchedule::Interleaved1F1B, p, m);
            assert!(
                (o.bubble - theory / INTERLEAVE_DEGREE as f64).abs() < 1e-9,
                "interleaved p={p} m={m}: bubble {}",
                o.bubble
            );
        }
    }

    /// No deadlock and bounded in-flight for every (schedule, p, m) the
    /// planner can produce — including partial interleave groups (ghost
    /// padding) and asymmetric fwd/bwd durations with hop delays.
    #[test]
    fn deadlock_free_and_inflight_bounded_across_shapes() {
        for sched in [
            PipeSchedule::OneFOneB,
            PipeSchedule::GPipe,
            PipeSchedule::Interleaved1F1B,
        ] {
            for p in 2..=8usize {
                for m in [1usize, 2, 3, 5, 7, 8, 12, 13, 16, 33, 96] {
                    let mut inp = PipeInputs {
                        sched,
                        pp: p,
                        num_micro: m,
                        fwd_total: m as f64,
                        bwd_total: 2.0 * m as f64,
                        blocking_fwd_micro: 0.1,
                        blocking_bwd_micro: 0.2,
                        ovl_micro: 0.3,
                        ovl_step: 0.4,
                        hop: 0.05,
                        overlap: true,
                    };
                    let o = simulate_pipeline(&inp);
                    let bound = crate::parallel::live_microbatches(sched, p, m);
                    assert!(
                        o.peak_inflight <= bound,
                        "{sched:?} p={p} m={m}: peak {} > live bound {bound}",
                        o.peak_inflight
                    );
                    assert!(o.makespan.is_finite() && o.bubble >= 0.0);
                    // serializing the streams can never be faster
                    inp.overlap = false;
                    let ser = simulate_pipeline(&inp);
                    assert!(ser.makespan >= o.makespan - 1e-9);
                }
            }
        }
    }

    /// Hop delays surface as measured bubble, not exposed comm.
    #[test]
    fn hops_appear_as_idle() {
        let base = run(PipeSchedule::OneFOneB, 4, 8);
        let hopped = simulate_pipeline(&PipeInputs {
            sched: PipeSchedule::OneFOneB,
            pp: 4,
            num_micro: 8,
            fwd_total: 8.0,
            bwd_total: 8.0,
            blocking_fwd_micro: 0.0,
            blocking_bwd_micro: 0.0,
            ovl_micro: 0.0,
            ovl_step: 0.0,
            hop: 0.25,
            overlap: true,
        });
        assert!(hopped.bubble > base.bubble);
        assert_eq!(hopped.exposed_grad, 0.0);
    }

    /// Comm-stream work hides behind backward windows at the documented
    /// efficiency; leftovers extend the critical stage.
    #[test]
    fn comm_stream_drains_against_backward() {
        let small = simulate_pipeline(&PipeInputs {
            sched: PipeSchedule::OneFOneB,
            pp: 2,
            num_micro: 8,
            fwd_total: 8.0,
            bwd_total: 8.0,
            blocking_fwd_micro: 0.0,
            blocking_bwd_micro: 0.0,
            ovl_micro: 0.1,
            ovl_step: 0.0,
            hop: 0.0,
            overlap: true,
        });
        assert!(small.exposed_grad < 1e-9, "light traffic fully hides");
        let heavy = simulate_pipeline(&PipeInputs {
            sched: PipeSchedule::OneFOneB,
            pp: 2,
            num_micro: 8,
            fwd_total: 8.0,
            bwd_total: 8.0,
            blocking_fwd_micro: 0.0,
            blocking_bwd_micro: 0.0,
            ovl_micro: 4.0,
            ovl_step: 0.0,
            hop: 0.0,
            overlap: true,
        });
        // 32s of traffic vs 0.85·8s of backward windows (+ idle gaps)
        assert!(heavy.exposed_grad > 20.0);
        assert!(heavy.makespan > small.makespan);
    }
}
