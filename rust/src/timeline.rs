//! Event-driven pipeline timeline engine — the per-micro-batch
//! discrete-event simulator that replaced [`crate::sim`]'s scalar
//! bubble/overlap heuristics.
//!
//! ## Task graph
//!
//! One training step is a DAG of `(stage, micro-batch, chunk)` compute
//! tasks.  Each physical pipeline stage executes a **static per-rank op
//! sequence** — the schedules' textbook definitions:
//!
//! * **GPipe**: all forwards, then all backwards (per-stage flush);
//! * **1F1B**: `p − 1 − s` warmup forwards, then strict 1-forward/
//!   1-backward alternation, then cooldown backwards;
//! * **Interleaved-1F1B**: each rank hosts
//!   [`INTERLEAVE_DEGREE`](crate::parallel::INTERLEAVE_DEGREE) virtual
//!   stages (model chunks); micro-batches traverse chunk-major groups of
//!   `p` (Megatron's traversal), warmup is `2(p−1−s) + (v−1)p` chunk
//!   forwards.  Megatron requires `m % p == 0`; the engine instead pads
//!   the last group with zero-duration, zero-delay **ghost micro-batches**
//!   so the same static order is deadlock-free for any `m` (ghosts
//!   enqueue no communication and do not count toward the in-flight
//!   peak).
//!
//! Cross-stage edges (activations forward, gradients backward) carry the
//! p2p transfer time as a **dependency delay**: the receiving stage idles
//! while the transfer is in flight, so pipeline communication surfaces as
//! measured bubble rather than a scalar "exposed" guess.
//!
//! ## Stream model
//!
//! Each stage owns two streams.  The **compute stream** runs the task
//! sequence; blocking collectives (TP all-reduces, ZeRO-3 forward
//! gathers, the forward halves of SP ring and MoE all-to-all) extend the
//! task durations.  The **comm stream** carries the overlappable classes
//! — ZeRO bucketed gradient reduction, the ZeRO-3 backward re-gather
//! (when prefetch is on), the backward halves of SP ring and MoE
//! all-to-all, and the sequence-parallel replicated-gradient all-reduce —
//! as a fluid backlog that drains at [`OVERLAP_EFFICIENCY`] of each
//! backward-compute window (DeepSpeed's bucketing overlaps backward, at
//! the same efficiency the closed form assumed) and at full rate during
//! idle gaps; whatever is left at the end of the stage's sequence extends
//! its finish time as exposed communication.  `overlap_comm = false`
//! **serializes the streams**: every comm-stream second is inlined into
//! the issuing backward task and nothing hides.
//!
//! ## Performance: skeletons and scratch arenas
//!
//! `simulate_pipeline` is the hot inner loop of every planner wave, HPO
//! funnel phase and sweep bench, so the engine is split into an immutable
//! **schedule skeleton** and a reusable **scratch arena**:
//!
//! * Everything *structural* — the per-rank static sequences, the dense
//!   task-id layout, the dependency graph (initial dependency counts plus
//!   a CSR waiter list with per-edge no-delay flags), ghost padding and
//!   the per-task decode tables — depends only on
//!   `(schedule, pp, num_micro)` and lives in a [`PipeSkeleton`], cached
//!   in a bounded, lock-striped global ([`skeletons`], the
//!   [`crate::sweep::SimCache`] striping pattern) with exact hit/miss
//!   counters.  Repeat shapes skip graph construction entirely.
//! * Every *per-simulation* mutable array (`ready_time`, stage cursors,
//!   busy/free state, interval logs, in-flight tracking and the event
//!   heap's backing vector) lives in a [`TimelineScratch`] that is
//!   **cleared, not freed**, between calls and threaded through
//!   [`simulate_pipeline`] via a thread-local — the steady-state engine
//!   is allocation-free ([`scratch_stats`] counts clears vs buffer
//!   growths, including mid-run heap/interval reallocation, so tests can
//!   assert it portably).  The arena lives as long as its thread: the
//!   calling thread keeps one for the process, a `Sweep` worker keeps
//!   one for the whole fan-out it serves.
//!
//! The event heap keeps the exact `(time, seq)` min-ordering of the
//! original engine — `(time, seq)` pairs are unique, so pop order (and
//! therefore every output float) is fully determined by the key set and
//! **bit-identical** to the pre-skeleton engine, whose verbatim body is
//! retained as a `#[cfg(test)]` reference and property-tested equal
//! across every `(schedule, pp ≤ 8, micro-batch count)` shape.
//!
//! ## Degeneracy guarantees
//!
//! For `pp == 1` the task graph is a serial chain with no idle gaps, so
//! the engine collapses to the closed form exactly:
//! `exposed = blocking + max(0, overlappable − 0.85·backward)` (or the
//! full sum with overlap off) — [`crate::sim::simulate_step`] evaluates
//! that case through the identical shared expressions, and the unit
//! tests assert bit-equality against the scalar reference.  Elsewhere the
//! engine stays within a property-tested band of the reference.

use crate::parallel::{PipeSchedule, INTERLEAVE_DEGREE};
use std::cell::RefCell;
use std::cmp::Ordering;
use std::collections::hash_map::DefaultHasher;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering as AtomicOrd};
use std::sync::{Arc, Mutex, OnceLock};

/// Fraction of a backward-compute window the comm stream can use
/// (DeepSpeed bucketing leaves some SM/copy-engine contention).
pub const OVERLAP_EFFICIENCY: f64 = 0.85;

/// Seconds of asynchronous checkpoint traffic one training step can
/// drain without touching the critical path — the fluid comm-stream
/// budget the resilience layer's async/tiered policies charge their
/// persist phase against.  The backward phase is 2/3 of each step (the
/// 1:2 forward:backward roofline split every pricing path uses) and the
/// comm stream drains at [`OVERLAP_EFFICIENCY`] of backward windows, so
/// each step hides at most `0.85 · (2/3) · step_s` seconds of drain.
/// Deliberately a function of the step time alone: the budget must be
/// identical for every candidate interval `m` so the piecewise interval
/// optimizer (`crate::resilience::optimal_interval_steps_policy`) stays
/// exact, and the resulting wall-per-period stays strictly increasing in
/// the step time (the coefficient is < 1), preserving the objective
/// monotonicity contract.
pub fn checkpoint_drain_budget(step_s: f64) -> f64 {
    OVERLAP_EFFICIENCY * (2.0 / 3.0) * step_s.max(0.0)
}

/// Per-step pipeline inputs, all in seconds per rank.
#[derive(Clone, Copy, Debug)]
pub struct PipeInputs {
    pub sched: PipeSchedule,
    /// Physical pipeline stages.  `pp == 1` degenerates to the closed
    /// form exactly ([`crate::sim`] evaluates that case analytically and
    /// the tests assert the engine agrees).
    pub pp: usize,
    /// Micro-batches per rank per step.
    pub num_micro: usize,
    /// Whole-step forward compute per stage.
    pub fwd_total: f64,
    /// Whole-step backward compute per stage.
    pub bwd_total: f64,
    /// Blocking comm inside each micro-batch's forward task (per-stage
    /// layer share).
    pub blocking_fwd_micro: f64,
    /// Blocking comm inside each micro-batch's backward task.
    pub blocking_bwd_micro: f64,
    /// Comm-stream seconds enqueued at each micro-batch's backward.
    pub ovl_micro: f64,
    /// Comm-stream seconds streamed uniformly across the backward phase
    /// (per-step gradient reduction).
    pub ovl_step: f64,
    /// p2p seconds per stage-boundary crossing.
    pub hop: f64,
    /// Overlap the comm stream with compute; `false` serializes.
    pub overlap: bool,
}

/// The engine's per-step outcome, decomposed on the critical stage.
#[derive(Clone, Copy, Debug)]
pub struct PipeOutcome {
    /// Wall time of the step's compute+comm window (excl. optimizer and
    /// input stall, which the caller adds).
    pub makespan: f64,
    /// Comm-stream seconds left exposed on the critical stage (all of
    /// them when overlap is off).
    pub exposed_grad: f64,
    /// Blocking comm on the critical stage.
    pub exposed_blocking: f64,
    /// Idle seconds on the critical stage (the measured bubble).
    pub bubble: f64,
    /// Stage index that set the makespan.
    pub critical_stage: usize,
    /// Largest number of real micro-batches simultaneously in flight on
    /// any stage (≤ [`crate::parallel::live_microbatches`]).
    pub peak_inflight: usize,
}

/// Megatron's interleaved traversal: groups of `p` micro-batches,
/// chunk-major inside a group.  `nm_pad` must be a multiple of `p`.
fn chunk_order(p: usize, nm_pad: usize, v: usize, reverse_chunks: bool) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(nm_pad * v);
    for g in 0..nm_pad / p {
        for cf in 0..v {
            let c = if reverse_chunks { v - 1 - cf } else { cf };
            for slot in 0..p {
                out.push((g * p + slot, c));
            }
        }
    }
    out
}

/// Static op sequence of physical stage `s`: `(is_bwd, micro, chunk)`.
/// Interleaved sequences include ghost micros `>= nm` (see module docs).
fn stage_sequence(
    sched: PipeSchedule,
    p: usize,
    s: usize,
    nm: usize,
    v: usize,
) -> Vec<(bool, usize, usize)> {
    let (fwd, bwd) = if sched == PipeSchedule::Interleaved1F1B {
        let nm_pad = ((nm + p - 1) / p) * p;
        (chunk_order(p, nm_pad, v, false), chunk_order(p, nm_pad, v, true))
    } else {
        (
            (0..nm).map(|m| (m, 0usize)).collect::<Vec<_>>(),
            (0..nm).map(|m| (m, 0usize)).collect::<Vec<_>>(),
        )
    };
    let total = fwd.len();
    if sched == PipeSchedule::GPipe {
        let mut seq: Vec<(bool, usize, usize)> =
            fwd.iter().map(|&(m, c)| (false, m, c)).collect();
        seq.extend(bwd.iter().map(|&(m, c)| (true, m, c)));
        return seq;
    }
    let warmup = match sched {
        PipeSchedule::OneFOneB => (p - 1 - s).min(total),
        _ => {
            let nm_pad = fwd.len() / v;
            if nm_pad == p {
                total
            } else {
                ((p - 1 - s) * 2 + (v - 1) * p).min(total)
            }
        }
    };
    let mut seq = Vec::with_capacity(2 * total);
    let (mut fc, mut bc) = (0usize, 0usize);
    while fc < warmup {
        let (m, c) = fwd[fc];
        seq.push((false, m, c));
        fc += 1;
    }
    while fc < total {
        let (m, c) = fwd[fc];
        seq.push((false, m, c));
        fc += 1;
        let (m, c) = bwd[bc];
        seq.push((true, m, c));
        bc += 1;
    }
    while bc < total {
        let (m, c) = bwd[bc];
        seq.push((true, m, c));
        bc += 1;
    }
    seq
}

/// Heap event, min-ordered by (time, seq) — `seq` makes ties (and the
/// whole simulation) deterministic.
#[derive(Clone, Copy, Debug)]
struct Event {
    time: f64,
    seq: u64,
    /// `usize::MAX` marks a stage wake-up; otherwise a completed task id.
    task: usize,
    stage: usize,
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Event) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

// ---------------------------------------------------------------------
// Schedule skeletons
// ---------------------------------------------------------------------

/// Structural identity of a pipeline problem — everything the engine
/// does that is independent of task durations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SkeletonKey {
    pub sched: PipeSchedule,
    pub pp: usize,
    pub num_micro: usize,
}

impl SkeletonKey {
    pub fn of(inp: &PipeInputs) -> SkeletonKey {
        SkeletonKey { sched: inp.sched, pp: inp.pp.max(1), num_micro: inp.num_micro.max(1) }
    }
}

/// The immutable, memoizable half of [`simulate_pipeline`]: static
/// per-rank sequences (as dense task ids), the dependency graph in CSR
/// form with per-edge no-delay flags, and per-task decode tables.  Built
/// once per [`SkeletonKey`] and shared via [`skeletons`].
pub struct PipeSkeleton {
    key: SkeletonKey,
    p: usize,
    nm: usize,
    v: usize,
    n_ids: usize,
    n_tasks: usize,
    /// Per-stage static op order, as dense task ids.
    seq_tasks: Vec<Vec<u32>>,
    /// Initial dependency count per task (≤ 2).
    ndeps0: Vec<u8>,
    /// CSR waiter lists: tasks unblocked when task `t` completes are
    /// `waiter_tgt[waiter_off[t]..waiter_off[t + 1]]`, in the exact
    /// insertion order of the original adjacency build.
    waiter_off: Vec<u32>,
    waiter_tgt: Vec<u32>,
    /// Per-edge: the same-stage same-micro (forward→backward) edges that
    /// carry no transfer delay.
    waiter_free: Vec<bool>,
    /// Per-task decode tables (replace the modulo/divide decode chains in
    /// the hot loop with straight lookups).
    task_bwd: Vec<bool>,
    task_ghost: Vec<bool>,
    task_stage: Vec<u32>,
    task_micro: Vec<u32>,
}

impl PipeSkeleton {
    /// Build the skeleton for one `(schedule, pp, num_micro)` shape —
    /// the structural work the pre-skeleton engine redid on every call.
    pub fn build(key: SkeletonKey) -> PipeSkeleton {
        let p = key.pp.max(1);
        let nm = key.num_micro.max(1);
        let v = if key.sched == PipeSchedule::Interleaved1F1B { INTERLEAVE_DEGREE } else { 1 };
        let nm_pad = if key.sched == PipeSchedule::Interleaved1F1B {
            ((nm + p - 1) / p) * p
        } else {
            nm
        };
        let seqs: Vec<Vec<(bool, usize, usize)>> =
            (0..p).map(|s| stage_sequence(key.sched, p, s, nm, v)).collect();

        // dense task ids: ((bwd·p + stage)·nm_pad + micro)·v + chunk
        let idx = |bwd: bool, st: usize, m: usize, c: usize| -> usize {
            (((bwd as usize) * p + st) * nm_pad + m) * v + c
        };
        let n_ids = 2 * p * nm_pad * v;
        let n_tasks: usize = seqs.iter().map(|s| s.len()).sum();

        // the dependency edges, in the exact order the original adjacency
        // build pushed them (source, target, same-stage-same-micro)
        let mut ndeps0 = vec![0u8; n_ids];
        let mut edges: Vec<(u32, u32, bool)> = Vec::with_capacity(2 * n_tasks);
        for (st, seq) in seqs.iter().enumerate() {
            for &(bwd, m, c) in seq {
                let t = idx(bwd, st, m, c);
                let mut add = |db: bool, dst: usize, dm: usize, dc: usize| {
                    let d = idx(db, dst, dm, dc);
                    ndeps0[t] += 1;
                    edges.push((d as u32, t as u32, dst == st && dm == m));
                };
                if !bwd {
                    if st > 0 {
                        add(false, st - 1, m, c);
                    } else if c > 0 {
                        add(false, p - 1, m, c - 1);
                    }
                } else {
                    add(false, st, m, c);
                    if st < p - 1 {
                        add(true, st + 1, m, c);
                    } else if c < v - 1 {
                        add(true, 0, m, c + 1);
                    }
                }
            }
        }
        // CSR over the sources; stable fill preserves per-source order
        let mut counts = vec![0u32; n_ids];
        for &(d, _, _) in &edges {
            counts[d as usize] += 1;
        }
        let mut waiter_off = vec![0u32; n_ids + 1];
        for i in 0..n_ids {
            waiter_off[i + 1] = waiter_off[i] + counts[i];
        }
        let mut cursor: Vec<u32> = waiter_off[..n_ids].to_vec();
        let mut waiter_tgt = vec![0u32; edges.len()];
        let mut waiter_free = vec![false; edges.len()];
        for &(d, t, free) in &edges {
            let slot = cursor[d as usize] as usize;
            waiter_tgt[slot] = t;
            waiter_free[slot] = free;
            cursor[d as usize] += 1;
        }

        let seq_tasks: Vec<Vec<u32>> = seqs
            .iter()
            .enumerate()
            .map(|(st, seq)| seq.iter().map(|&(bwd, m, c)| idx(bwd, st, m, c) as u32).collect())
            .collect();

        // per-task decode tables (the original engine's `decode` closure,
        // evaluated once at build time instead of per event per waiter)
        let mut task_bwd = vec![false; n_ids];
        let mut task_ghost = vec![false; n_ids];
        let mut task_stage = vec![0u32; n_ids];
        let mut task_micro = vec![0u32; n_ids];
        for t in 0..n_ids {
            let m = (t / v) % nm_pad;
            task_bwd[t] = t / v / nm_pad / p == 1;
            task_ghost[t] = m >= nm;
            task_stage[t] = ((t / v / nm_pad) % p) as u32;
            task_micro[t] = m as u32;
        }

        PipeSkeleton {
            key,
            p,
            nm,
            v,
            n_ids,
            n_tasks,
            seq_tasks,
            ndeps0,
            waiter_off,
            waiter_tgt,
            waiter_free,
            task_bwd,
            task_ghost,
            task_stage,
            task_micro,
        }
    }

    pub fn key(&self) -> SkeletonKey {
        self.key
    }

    pub fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    /// Approximate resident weight in dense task ids — every table in
    /// the skeleton is O(`n_ids`) (~30 bytes per id across them), so the
    /// cache budgets by this rather than by entry count alone.
    pub fn weight(&self) -> usize {
        self.n_ids
    }
}

/// Default bound on resident skeleton *entries*; override with
/// `SCALESTUDY_SKELCACHE_MAX` (0 = unbounded).
pub const SKELETON_CACHE_DEFAULT_MAX: usize = 1024;

/// Default bound on total resident skeleton *weight* (task ids summed
/// across entries).  Shapes vary 1000× in size — a pp=8, 768-micro-batch
/// interleaved skeleton is ~25k ids (~700 KB) while typical planner
/// shapes are a few hundred — so a count bound alone could pin hundreds
/// of MB.  1M ids ≈ ~30 MB worst case.  Override with
/// `SCALESTUDY_SKELCACHE_MAX_TASKS` (0 = unbounded).
pub const SKELETON_CACHE_DEFAULT_MAX_TASKS: usize = 1 << 20;

const SKELETON_STRIPES: usize = 16;

fn skeleton_default_max() -> usize {
    crate::sweep::env_usize_or("SCALESTUDY_SKELCACHE_MAX", SKELETON_CACHE_DEFAULT_MAX)
}

fn skeleton_default_max_tasks() -> usize {
    crate::sweep::env_usize_or(
        "SCALESTUDY_SKELCACHE_MAX_TASKS",
        SKELETON_CACHE_DEFAULT_MAX_TASKS,
    )
}

/// Bounded, lock-striped memo cache over [`PipeSkeleton::build`] — the
/// [`crate::sweep::SimCache`] pattern: one stripe-lock acquisition per
/// [`SkeletonCache::get`] (a miss builds under its stripe, so same-key
/// racers wait for the built skeleton instead of duplicating the work),
/// exact hit/miss counters under any interleaving, and oldest-insertion
/// eviction past **either** budget — entry count, or total task-id
/// weight (shapes vary ~1000× in size, so the weight budget is what
/// actually bounds memory).  Eviction only drops the cache's `Arc` —
/// in-flight simulations keep their skeleton alive, so results can
/// never change under memory pressure (property-tested).
///
/// The striping/eviction mechanism deliberately mirrors `SimCache`
/// rather than sharing a generic with it: `SimCache` interleaves
/// persistence with the same state, and unifying the two is a refactor
/// best done with a compiler in the loop.  Fixes to either cache's
/// locking or eviction should be ported to the other.
pub struct SkeletonCache {
    stripes: Vec<Mutex<HashMap<SkeletonKey, (Arc<PipeSkeleton>, u64)>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
    entries: AtomicUsize,
    /// Total resident weight (sum of [`PipeSkeleton::weight`]).
    weight: AtomicUsize,
    seq: AtomicU64,
    ages: Mutex<VecDeque<(SkeletonKey, u64)>>,
    max_entries: usize,
    max_weight: usize,
}

impl Default for SkeletonCache {
    fn default() -> SkeletonCache {
        SkeletonCache::new()
    }
}

impl SkeletonCache {
    pub fn new() -> SkeletonCache {
        SkeletonCache::with_budget(skeleton_default_max(), skeleton_default_max_tasks())
    }

    /// A cache bounded to `max_entries` resident skeletons (0 =
    /// unbounded), with the default weight budget.
    pub fn with_capacity(max_entries: usize) -> SkeletonCache {
        SkeletonCache::with_budget(max_entries, skeleton_default_max_tasks())
    }

    /// Bound both the entry count and the total task-id weight (either
    /// 0 = unbounded on that axis).
    pub fn with_budget(max_entries: usize, max_weight: usize) -> SkeletonCache {
        SkeletonCache {
            stripes: (0..SKELETON_STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            entries: AtomicUsize::new(0),
            weight: AtomicUsize::new(0),
            seq: AtomicU64::new(0),
            ages: Mutex::new(VecDeque::new()),
            max_entries,
            max_weight,
        }
    }

    fn over_budget(&self) -> bool {
        (self.max_entries > 0 && self.entries.load(AtomicOrd::Relaxed) > self.max_entries)
            || (self.max_weight > 0 && self.weight.load(AtomicOrd::Relaxed) > self.max_weight)
    }

    fn stripe_of(&self, key: &SkeletonKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.stripes.len()
    }

    fn next_seq_and_track(&self, key: SkeletonKey) -> u64 {
        let mut ages = self.ages.lock().unwrap();
        let seq = self.seq.fetch_add(1, AtomicOrd::Relaxed);
        ages.push_back((key, seq));
        seq
    }

    /// Evict the globally oldest-inserted entry; `false` when the age
    /// queue is exhausted (nothing evictable), which bounds the caller's
    /// eviction loop even if a concurrent [`SkeletonCache::clear`]
    /// orphaned entries from their age records.
    fn evict_oldest(&self) -> bool {
        loop {
            let front = { self.ages.lock().unwrap().pop_front() };
            let (k, s) = match front {
                Some(f) => f,
                None => return false,
            };
            let mut map = self.stripes[self.stripe_of(&k)].lock().unwrap();
            if map.get(&k).map_or(false, |&(_, cs)| cs == s) {
                if let Some((gone, _)) = map.remove(&k) {
                    self.entries.fetch_sub(1, AtomicOrd::Relaxed);
                    self.weight.fetch_sub(gone.weight(), AtomicOrd::Relaxed);
                    self.evictions.fetch_add(1, AtomicOrd::Relaxed);
                }
                return true;
            }
        }
    }

    /// The cached skeleton for `key`, building it on a miss (under the
    /// stripe lock, so concurrent same-key callers wait instead of
    /// duplicating the build).  Past either budget, oldest-inserted
    /// entries are evicted (never down to empty — the newest skeleton
    /// stays resident even if it alone exceeds the weight budget).
    pub fn get(&self, key: SkeletonKey) -> Arc<PipeSkeleton> {
        let skel = {
            let mut map = self.stripes[self.stripe_of(&key)].lock().unwrap();
            if let Some((hit, _)) = map.get(&key) {
                self.hits.fetch_add(1, AtomicOrd::Relaxed);
                return hit.clone();
            }
            let built = Arc::new(PipeSkeleton::build(key));
            self.misses.fetch_add(1, AtomicOrd::Relaxed);
            let seq = self.next_seq_and_track(key);
            self.weight.fetch_add(built.weight(), AtomicOrd::Relaxed);
            map.insert(key, (built.clone(), seq));
            self.entries.fetch_add(1, AtomicOrd::Relaxed);
            built
        };
        while self.over_budget() && self.entries.load(AtomicOrd::Relaxed) > 1 {
            if !self.evict_oldest() {
                break;
            }
        }
        skel
    }

    pub fn hits(&self) -> usize {
        self.hits.load(AtomicOrd::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(AtomicOrd::Relaxed)
    }

    /// Entries evicted past either budget since construction — surfaced
    /// by the `cache` CLI subcommand and the server's `stats` query so
    /// warm-pool claims are inspectable.
    pub fn evictions(&self) -> usize {
        self.evictions.load(AtomicOrd::Relaxed)
    }

    /// Hit fraction of all `get` calls so far (0.0 when none).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits(), self.misses());
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total resident weight in task ids (the budgeted quantity).
    pub fn resident_weight(&self) -> usize {
        self.weight.load(AtomicOrd::Relaxed)
    }

    /// Drop every resident skeleton (counters keep accumulating) — a
    /// test/tooling hook for exercising cold starts on a long-lived
    /// cache.  Not safe to rely on for *exact* accounting while
    /// concurrent `get`s run (an interleaved insert can survive with its
    /// age record wiped; such orphans are still evicted-by-count and
    /// never hang the eviction loop, which stops when the age queue is
    /// exhausted).
    pub fn clear(&self) {
        for stripe in &self.stripes {
            let mut map = stripe.lock().unwrap();
            for (_, (skel, _)) in map.iter() {
                self.weight.fetch_sub(skel.weight(), AtomicOrd::Relaxed);
            }
            let n = map.len();
            map.clear();
            self.entries.fetch_sub(n, AtomicOrd::Relaxed);
        }
        self.ages.lock().unwrap().clear();
    }
}

static SKELETONS: OnceLock<SkeletonCache> = OnceLock::new();

/// The process-wide skeleton cache [`simulate_pipeline`] prices through.
pub fn skeletons() -> &'static SkeletonCache {
    SKELETONS.get_or_init(SkeletonCache::new)
}

/// Ensure `key`'s skeleton is resident — the batch-pricing entry points
/// ([`crate::sim::simulate_batch`], the planner's waves) warm each
/// distinct shape once before fanning a group out across workers.
pub fn warm_skeleton(key: SkeletonKey) {
    let _ = skeletons().get(key);
}

// ---------------------------------------------------------------------
// Scratch arenas
// ---------------------------------------------------------------------

/// The mutable half of a simulation: every per-run array, cleared-not-
/// freed between calls so the steady-state engine allocates nothing.
/// One lives per thread (see [`simulate_pipeline`]); tests and benches
/// can also hold their own.
pub struct TimelineScratch {
    ndeps: Vec<u8>,
    ready_time: Vec<f64>,
    ptr: Vec<usize>,
    busy: Vec<bool>,
    free_at: Vec<f64>,
    stage_last_end: Vec<f64>,
    // (span, is_bwd, is_idle, bwd_compute_span) intervals per stage
    intervals: Vec<Vec<(f64, bool, bool, f64)>>,
    inflight: Vec<usize>,
    fwd_started: Vec<bool>,
    bwd_done: Vec<u32>,
    heap: Vec<Event>,
    clears: u64,
    grows: u64,
}

impl Default for TimelineScratch {
    fn default() -> TimelineScratch {
        TimelineScratch::new()
    }
}

impl TimelineScratch {
    pub fn new() -> TimelineScratch {
        TimelineScratch {
            ndeps: Vec::new(),
            ready_time: Vec::new(),
            ptr: Vec::new(),
            busy: Vec::new(),
            free_at: Vec::new(),
            stage_last_end: Vec::new(),
            intervals: Vec::new(),
            inflight: Vec::new(),
            fwd_started: Vec::new(),
            bwd_done: Vec::new(),
            heap: Vec::new(),
            clears: 0,
            grows: 0,
        }
    }

    /// Clear (never free) every array and size it for `skel`.  Counts a
    /// clear always and a grow only when some backing buffer had to
    /// reallocate — the no-allocation smoke test's portable signal.
    fn reset(&mut self, skel: &PipeSkeleton) {
        self.clears += 1;
        let (p, n_ids, slots) = (skel.p, skel.n_ids, skel.p * skel.nm);
        let mut grew = false;
        grew |= self.ndeps.capacity() < n_ids;
        self.ndeps.clear();
        self.ndeps.extend_from_slice(&skel.ndeps0);
        grew |= self.ready_time.capacity() < n_ids;
        self.ready_time.clear();
        self.ready_time.resize(n_ids, 0.0);
        grew |= self.ptr.capacity() < p;
        self.ptr.clear();
        self.ptr.resize(p, 0);
        grew |= self.busy.capacity() < p;
        self.busy.clear();
        self.busy.resize(p, false);
        grew |= self.free_at.capacity() < p;
        self.free_at.clear();
        self.free_at.resize(p, 0.0);
        grew |= self.stage_last_end.capacity() < p;
        self.stage_last_end.clear();
        self.stage_last_end.resize(p, 0.0);
        grew |= self.inflight.capacity() < p;
        self.inflight.clear();
        self.inflight.resize(p, 0);
        grew |= self.fwd_started.capacity() < slots;
        self.fwd_started.clear();
        self.fwd_started.resize(slots, false);
        grew |= self.bwd_done.capacity() < slots;
        self.bwd_done.clear();
        self.bwd_done.resize(slots, 0);
        // the interval logs keep their inner capacity across runs
        grew |= self.intervals.capacity() < p;
        while self.intervals.len() < p {
            self.intervals.push(Vec::new());
        }
        for iv in self.intervals.iter_mut().take(p) {
            iv.clear();
        }
        self.heap.clear();
        if grew {
            self.grows += 1;
        }
    }

    /// `(clears, grows)` so far: a warm arena keeps clearing without
    /// growing.
    pub fn stats(&self) -> (u64, u64) {
        (self.clears, self.grows)
    }
}

thread_local! {
    static SCRATCH: RefCell<TimelineScratch> = RefCell::new(TimelineScratch::new());
}

/// This thread's arena counters — `(clears, grows)` — for the
/// no-allocation smoke assertions (count clears, not allocations, to
/// stay portable across allocators).
pub fn scratch_stats() -> (u64, u64) {
    SCRATCH.with(|s| s.borrow().stats())
}

// ---------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------

/// Simulate one step's pipeline through the process-wide skeleton cache
/// and this thread's scratch arena.  Panics on an internal scheduling
/// inconsistency (a structural deadlock), which the static sequences are
/// property-tested never to produce for any `(schedule, pp, num_micro)`.
pub fn simulate_pipeline(inp: &PipeInputs) -> PipeOutcome {
    let skel = skeletons().get(SkeletonKey::of(inp));
    SCRATCH.with(|s| simulate_pipeline_with(&skel, &mut s.borrow_mut(), inp))
}

/// The cold path — build a fresh skeleton and a fresh arena for this one
/// call (exactly the pre-memoization cost).  The benches use it as the
/// honest baseline; results are bit-identical to [`simulate_pipeline`].
pub fn simulate_pipeline_uncached(inp: &PipeInputs) -> PipeOutcome {
    let skel = PipeSkeleton::build(SkeletonKey::of(inp));
    let mut scratch = TimelineScratch::new();
    simulate_pipeline_with(&skel, &mut scratch, inp)
}

/// Deterministic per-task compute perturbation for the jitter axis: the
/// compute chunk of dense task id `t` in sample `sample` is scaled by
/// [`TaskJitter::factor`], a pure splitmix64 hash of
/// `(seed, sample, task)` mapped to a uniform in `[1−spread, 1+spread]`.
/// Blocking comm, hop delays and the overlappable stream are left
/// untouched — jitter models compute stragglers per micro-batch, not the
/// network.  Being a pure hash (no sequential RNG state), a jittered
/// trace is identical regardless of thread, call order or worker count.
#[derive(Clone, Copy, Debug)]
pub struct TaskJitter {
    seed: u64,
    sample: u64,
    spread: f64,
}

impl TaskJitter {
    /// `spread` is clamped to `[0, 0.95]` so factors stay positive.
    pub fn new(seed: u64, sample: u64, spread: f64) -> TaskJitter {
        TaskJitter { seed, sample, spread: spread.clamp(0.0, 0.95) }
    }

    /// Multiplicative compute factor for dense task id `task`.
    pub fn factor(&self, task: u64) -> f64 {
        let mut z = self
            .seed
            .wrapping_add(self.sample.wrapping_mul(0xA24B_AED4_963E_E407))
            .wrapping_add(task.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        let u = (z >> 11) as f64 * (1.0 / 9007199254740992.0); // 53-bit
        1.0 + self.spread * (2.0 * u - 1.0)
    }
}

/// One jittered sample of a step: every task's compute chunk is scaled
/// by the `(seed, sample)` trace's per-task factor before the event
/// simulation runs, so stragglers propagate through real pipeline
/// dependencies instead of a scalar slowdown.  `spread <= 0` returns
/// [`simulate_pipeline`] unchanged — the degenerate case is the
/// deterministic engine itself, bit for bit.  Note the makespan is
/// measured on the perturbed trace while the outcome's `bubble`
/// decomposition still subtracts the unperturbed compute totals; callers
/// of jittered sampling consume the makespan.
pub fn simulate_pipeline_jittered(
    inp: &PipeInputs,
    seed: u64,
    sample: u64,
    spread: f64,
) -> PipeOutcome {
    if !(spread > 0.0) {
        return simulate_pipeline(inp);
    }
    let jitter = TaskJitter::new(seed, sample, spread);
    let skel = skeletons().get(SkeletonKey::of(inp));
    SCRATCH.with(|s| simulate_pipeline_impl(&skel, &mut s.borrow_mut(), inp, Some(&jitter)))
}

/// The optimized engine over an explicit skeleton + arena.  `skel` must
/// match `inp`'s `(schedule, pp, num_micro)` shape.
pub fn simulate_pipeline_with(
    skel: &PipeSkeleton,
    scratch: &mut TimelineScratch,
    inp: &PipeInputs,
) -> PipeOutcome {
    simulate_pipeline_impl(skel, scratch, inp, None)
}

/// The engine body.  With `jitter: None` every duration expression is
/// the verbatim unperturbed path (property-tested bit-identical to the
/// retained reference); with a jitter, each non-ghost task's compute
/// chunk is scaled by its per-task factor while the blocking-comm share
/// of the duration stays fixed, and backward drain windows shrink/grow
/// with the perturbed chunk so the fluid comm stream sees the jittered
/// timeline too.
fn simulate_pipeline_impl(
    skel: &PipeSkeleton,
    scratch: &mut TimelineScratch,
    inp: &PipeInputs,
    jitter: Option<&TaskJitter>,
) -> PipeOutcome {
    debug_assert_eq!(skel.key, SkeletonKey::of(inp), "skeleton/inputs shape mismatch");
    let p = skel.p;
    let nm = skel.nm;
    let v = skel.v;
    let vf = v as f64;
    let nmf = nm as f64;
    let fwd_chunk = inp.fwd_total / nmf / vf;
    let bwd_chunk = inp.bwd_total / nmf / vf;
    let per_bwd_work = inp.ovl_micro / vf + inp.ovl_step / (nmf * vf);
    let fwd_dur = fwd_chunk + inp.blocking_fwd_micro / vf;
    let mut bwd_dur = bwd_chunk + inp.blocking_bwd_micro / vf;
    if !inp.overlap {
        bwd_dur += per_bwd_work; // serialize the streams
    }

    scratch.reset(skel);
    let mut heap: BinaryHeap<Event> = BinaryHeap::from(std::mem::take(&mut scratch.heap));
    // capacity snapshots so mid-run reallocation of the push-grown
    // buffers (heap, interval logs) is counted as a grow too — reset()
    // can only check the arrays it sizes up-front
    let heap_cap0 = heap.capacity();
    let ivals_cap0: usize = scratch.intervals.iter().take(p).map(|iv| iv.capacity()).sum();
    let mut evseq = 0u64;
    let mut n_done = 0usize;
    let mut peak_inflight = 0usize;

    macro_rules! dispatch {
        ($st:expr, $now:expr) => {{
            let st = $st;
            let now: f64 = $now;
            if !scratch.busy[st] && scratch.ptr[st] < skel.seq_tasks[st].len() {
                let t = skel.seq_tasks[st][scratch.ptr[st]] as usize;
                if scratch.ndeps[t] == 0 {
                    let rt = scratch.ready_time[t];
                    if rt > now {
                        heap.push(Event { time: rt, seq: evseq, task: usize::MAX, stage: st });
                        evseq += 1;
                    } else {
                        let ghost = skel.task_ghost[t];
                        let bwd = skel.task_bwd[t];
                        let start =
                            if scratch.free_at[st] > now { scratch.free_at[st] } else { now };
                        if !bwd && !ghost {
                            let slot = st * nm + skel.task_micro[t] as usize;
                            if !scratch.fwd_started[slot] {
                                scratch.fwd_started[slot] = true;
                                scratch.inflight[st] += 1;
                                peak_inflight = peak_inflight.max(scratch.inflight[st]);
                            }
                        }
                        scratch.busy[st] = true;
                        scratch.ptr[st] += 1;
                        let (dur, bspan) = if ghost {
                            (0.0, 0.0)
                        } else if let Some(j) = jitter {
                            // scale only the compute chunk; the blocking
                            // comm share of the duration is unperturbed
                            let f = j.factor(t as u64);
                            if bwd {
                                (bwd_chunk * f + (bwd_dur - bwd_chunk), bwd_chunk * f)
                            } else {
                                (fwd_chunk * f + (fwd_dur - fwd_chunk), 0.0)
                            }
                        } else if bwd {
                            (bwd_dur, bwd_chunk)
                        } else {
                            (fwd_dur, 0.0)
                        };
                        let end = start + dur;
                        if !ghost {
                            if start > scratch.stage_last_end[st] {
                                scratch.intervals[st].push((
                                    start - scratch.stage_last_end[st],
                                    false,
                                    true,
                                    0.0,
                                ));
                            }
                            scratch.intervals[st].push((dur, bwd, false, bspan));
                            scratch.stage_last_end[st] = end;
                        }
                        scratch.free_at[st] = end;
                        heap.push(Event { time: end, seq: evseq, task: t, stage: st });
                        evseq += 1;
                    }
                }
            }
        }};
    }

    for st in 0..p {
        dispatch!(st, 0.0);
    }
    while let Some(ev) = heap.pop() {
        if ev.task == usize::MAX {
            dispatch!(ev.stage, ev.time);
            continue;
        }
        let t = ev.task;
        let st = skel.task_stage[t] as usize;
        n_done += 1;
        scratch.busy[st] = false;
        let ghost = skel.task_ghost[t];
        if skel.task_bwd[t] && !ghost {
            let slot = st * nm + skel.task_micro[t] as usize;
            scratch.bwd_done[slot] += 1;
            if scratch.bwd_done[slot] as usize == v {
                scratch.inflight[st] -= 1;
            }
        }
        let hop = if ghost { 0.0 } else { inp.hop };
        let (w0, w1) = (skel.waiter_off[t] as usize, skel.waiter_off[t + 1] as usize);
        for wi in w0..w1 {
            let w = skel.waiter_tgt[wi] as usize;
            scratch.ndeps[w] -= 1;
            // same-stage forward→backward edges carry no transfer
            let delay = if skel.waiter_free[wi] { 0.0 } else { hop };
            let rt = ev.time + delay;
            if rt > scratch.ready_time[w] {
                scratch.ready_time[w] = rt;
            }
        }
        for st2 in 0..p {
            dispatch!(st2, ev.time);
        }
    }
    // hand the (drained) heap's buffer back to the arena
    let heap_grew = heap.capacity() > heap_cap0;
    scratch.heap = heap.into_vec();
    scratch.heap.clear();
    let ivals_cap1: usize = scratch.intervals.iter().take(p).map(|iv| iv.capacity()).sum();
    if heap_grew || ivals_cap1 > ivals_cap0 {
        scratch.grows += 1;
    }
    assert_eq!(
        n_done, skel.n_tasks,
        "pipeline deadlock: {n_done}/{} ({:?}, p={p}, m={nm})",
        skel.n_tasks, inp.sched
    );

    // ---- fluid comm-stream drain per stage
    let mut makespan = f64::NEG_INFINITY;
    let mut crit = 0usize;
    let mut crit_backlog = 0.0f64;
    for st in 0..p {
        let mut backlog = 0.0f64;
        if inp.overlap {
            for &(span, is_bwd, is_idle, bspan) in &scratch.intervals[st] {
                if is_bwd {
                    let avail = backlog + per_bwd_work;
                    let drained = avail.min(OVERLAP_EFFICIENCY * bspan);
                    backlog = avail - drained;
                } else if is_idle {
                    backlog -= backlog.min(span);
                }
            }
        }
        let finish = scratch.stage_last_end[st] + backlog;
        if finish > makespan {
            makespan = finish;
            crit = st;
            crit_backlog = backlog;
        }
    }
    let compute_st = inp.fwd_total + inp.bwd_total;
    let blocking = (inp.blocking_fwd_micro + inp.blocking_bwd_micro) * nmf;
    let ovl_total = inp.ovl_micro * nmf + inp.ovl_step;
    let exposed_grad = if inp.overlap { crit_backlog } else { ovl_total };
    let idle = makespan - compute_st - blocking - exposed_grad;
    PipeOutcome {
        makespan,
        exposed_grad,
        exposed_blocking: blocking,
        bubble: idle.max(0.0),
        critical_stage: crit,
        peak_inflight,
    }
}

/// The pre-skeleton engine body, kept verbatim as the bit-identity
/// reference: rebuilds the per-rank sequences, the adjacency lists and
/// every scratch vector on each call.  [`simulate_pipeline`] is
/// property-tested bit-equal to this across every
/// `(schedule, pp ≤ 8, micro-batch count)` shape.
#[cfg(test)]
pub(crate) fn simulate_pipeline_reference(inp: &PipeInputs) -> PipeOutcome {
    let p = inp.pp.max(1);
    let nm = inp.num_micro.max(1);
    let v = if inp.sched == PipeSchedule::Interleaved1F1B { INTERLEAVE_DEGREE } else { 1 };
    let nm_pad = if inp.sched == PipeSchedule::Interleaved1F1B {
        ((nm + p - 1) / p) * p
    } else {
        nm
    };
    let vf = v as f64;
    let nmf = nm as f64;
    let fwd_chunk = inp.fwd_total / nmf / vf;
    let bwd_chunk = inp.bwd_total / nmf / vf;
    let per_bwd_work = inp.ovl_micro / vf + inp.ovl_step / (nmf * vf);
    let fwd_dur = fwd_chunk + inp.blocking_fwd_micro / vf;
    let mut bwd_dur = bwd_chunk + inp.blocking_bwd_micro / vf;
    if !inp.overlap {
        bwd_dur += per_bwd_work; // serialize the streams
    }

    let seqs: Vec<Vec<(bool, usize, usize)>> =
        (0..p).map(|s| stage_sequence(inp.sched, p, s, nm, v)).collect();

    // dense task ids: ((bwd·p + stage)·nm_pad + micro)·v + chunk
    let idx = |bwd: bool, st: usize, m: usize, c: usize| -> usize {
        (((bwd as usize) * p + st) * nm_pad + m) * v + c
    };
    let n_ids = 2 * p * nm_pad * v;
    let mut ndeps = vec![0u8; n_ids];
    let mut waiters: Vec<Vec<usize>> = vec![Vec::new(); n_ids];
    for (st, seq) in seqs.iter().enumerate() {
        for &(bwd, m, c) in seq {
            let t = idx(bwd, st, m, c);
            let mut add = |d: usize| {
                ndeps[t] += 1;
                waiters[d].push(t);
            };
            if !bwd {
                if st > 0 {
                    add(idx(false, st - 1, m, c));
                } else if c > 0 {
                    add(idx(false, p - 1, m, c - 1));
                }
            } else {
                add(idx(false, st, m, c));
                if st < p - 1 {
                    add(idx(true, st + 1, m, c));
                } else if c < v - 1 {
                    add(idx(true, 0, m, c + 1));
                }
            }
        }
    }

    let decode = |t: usize| -> (bool, usize, usize, usize) {
        let c = t % v;
        let m = (t / v) % nm_pad;
        let st = (t / v / nm_pad) % p;
        let bwd = t / v / nm_pad / p == 1;
        (bwd, st, m, c)
    };

    let mut ready_time = vec![0.0f64; n_ids];
    let mut ptr = vec![0usize; p];
    let mut busy = vec![false; p];
    let mut free_at = vec![0.0f64; p];
    let mut n_done = 0usize;
    let n_tasks: usize = seqs.iter().map(|s| s.len()).sum();
    let mut stage_last_end = vec![0.0f64; p];
    // (span, is_bwd, is_idle, bwd_compute_span) intervals per stage
    let mut intervals: Vec<Vec<(f64, bool, bool, f64)>> = vec![Vec::new(); p];
    let mut inflight = vec![0usize; p];
    let mut peak_inflight = 0usize;
    let mut fwd_started: Vec<Vec<bool>> = vec![vec![false; nm]; p];
    let mut bwd_done_count: Vec<Vec<usize>> = vec![vec![0; nm]; p];

    let mut heap: BinaryHeap<Event> = BinaryHeap::new();
    let mut evseq = 0u64;

    macro_rules! dispatch {
        ($st:expr, $now:expr) => {{
            let st = $st;
            let now: f64 = $now;
            if !busy[st] && ptr[st] < seqs[st].len() {
                let (bwd, m, c) = seqs[st][ptr[st]];
                let t = idx(bwd, st, m, c);
                if ndeps[t] == 0 {
                    let rt = ready_time[t];
                    if rt > now {
                        heap.push(Event { time: rt, seq: evseq, task: usize::MAX, stage: st });
                        evseq += 1;
                    } else {
                        let ghost = m >= nm;
                        let start = if free_at[st] > now { free_at[st] } else { now };
                        if !bwd && !ghost && !fwd_started[st][m] {
                            fwd_started[st][m] = true;
                            inflight[st] += 1;
                            peak_inflight = peak_inflight.max(inflight[st]);
                        }
                        busy[st] = true;
                        ptr[st] += 1;
                        let dur = if ghost {
                            0.0
                        } else if bwd {
                            bwd_dur
                        } else {
                            fwd_dur
                        };
                        let end = start + dur;
                        if !ghost {
                            if start > stage_last_end[st] {
                                intervals[st].push((
                                    start - stage_last_end[st],
                                    false,
                                    true,
                                    0.0,
                                ));
                            }
                            intervals[st].push((
                                dur,
                                bwd,
                                false,
                                if bwd { bwd_chunk } else { 0.0 },
                            ));
                            stage_last_end[st] = end;
                        }
                        free_at[st] = end;
                        heap.push(Event { time: end, seq: evseq, task: t, stage: st });
                        evseq += 1;
                    }
                }
            }
        }};
    }

    for st in 0..p {
        dispatch!(st, 0.0);
    }
    while let Some(ev) = heap.pop() {
        if ev.task == usize::MAX {
            dispatch!(ev.stage, ev.time);
            continue;
        }
        let (bwd, st, m, _c) = decode(ev.task);
        n_done += 1;
        busy[st] = false;
        if bwd && m < nm {
            bwd_done_count[st][m] += 1;
            if bwd_done_count[st][m] == v {
                inflight[st] -= 1;
            }
        }
        let hop = if m >= nm { 0.0 } else { inp.hop };
        for wi in 0..waiters[ev.task].len() {
            let w = waiters[ev.task][wi];
            ndeps[w] -= 1;
            let (_, wst, wm, _) = decode(w);
            // same-stage forward→backward edges carry no transfer
            let delay = if wst == st && wm == m { 0.0 } else { hop };
            let rt = ev.time + delay;
            if rt > ready_time[w] {
                ready_time[w] = rt;
            }
        }
        for st2 in 0..p {
            dispatch!(st2, ev.time);
        }
    }
    assert_eq!(
        n_done, n_tasks,
        "pipeline deadlock: {n_done}/{n_tasks} ({:?}, p={p}, m={nm})",
        inp.sched
    );

    // ---- fluid comm-stream drain per stage
    let mut makespan = f64::NEG_INFINITY;
    let mut crit = 0usize;
    let mut crit_backlog = 0.0f64;
    for st in 0..p {
        let mut backlog = 0.0f64;
        if inp.overlap {
            for &(span, is_bwd, is_idle, bspan) in &intervals[st] {
                if is_bwd {
                    let avail = backlog + per_bwd_work;
                    let drained = avail.min(OVERLAP_EFFICIENCY * bspan);
                    backlog = avail - drained;
                } else if is_idle {
                    backlog -= backlog.min(span);
                }
            }
        }
        let finish = stage_last_end[st] + backlog;
        if finish > makespan {
            makespan = finish;
            crit = st;
            crit_backlog = backlog;
        }
    }
    let compute_st = inp.fwd_total + inp.bwd_total;
    let blocking = (inp.blocking_fwd_micro + inp.blocking_bwd_micro) * nmf;
    let ovl_total = inp.ovl_micro * nmf + inp.ovl_step;
    let exposed_grad = if inp.overlap { crit_backlog } else { ovl_total };
    let idle = makespan - compute_st - blocking - exposed_grad;
    PipeOutcome {
        makespan,
        exposed_grad,
        exposed_blocking: blocking,
        bubble: idle.max(0.0),
        critical_stage: crit,
        peak_inflight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(sched: PipeSchedule, p: usize, m: usize) -> PipeOutcome {
        simulate_pipeline(&PipeInputs {
            sched,
            pp: p,
            num_micro: m,
            fwd_total: m as f64,
            bwd_total: m as f64,
            blocking_fwd_micro: 0.0,
            blocking_bwd_micro: 0.0,
            ovl_micro: 0.0,
            ovl_step: 0.0,
            hop: 0.0,
            overlap: true,
        })
    }

    fn assert_outcomes_bit_identical(a: &PipeOutcome, b: &PipeOutcome, tag: &str) {
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{tag}: makespan");
        assert_eq!(a.exposed_grad.to_bits(), b.exposed_grad.to_bits(), "{tag}: exposed_grad");
        assert_eq!(
            a.exposed_blocking.to_bits(),
            b.exposed_blocking.to_bits(),
            "{tag}: exposed_blocking"
        );
        assert_eq!(a.bubble.to_bits(), b.bubble.to_bits(), "{tag}: bubble");
        assert_eq!(a.critical_stage, b.critical_stage, "{tag}: critical_stage");
        assert_eq!(a.peak_inflight, b.peak_inflight, "{tag}: peak_inflight");
    }

    /// THE tentpole acceptance property: the skeleton/arena engine is
    /// **bit-identical** to the retained pre-memoization reference body
    /// for every (schedule, pp ≤ 8, micro-batch count) shape, with
    /// overlap on/off, asymmetric durations, hop delays, and both
    /// comm-class splits (the zero3_prefetch knob moves seconds between
    /// `blocking_bwd_micro` and `ovl_micro` — both splits are swept).
    #[test]
    fn optimized_engine_bit_identical_to_reference() {
        for sched in [
            PipeSchedule::OneFOneB,
            PipeSchedule::GPipe,
            PipeSchedule::Interleaved1F1B,
        ] {
            for p in 1..=8usize {
                for m in [1usize, 2, 3, 5, 7, 8, 12, 13, 16, 33, 96] {
                    for overlap in [true, false] {
                        // (blocking_bwd, ovl_micro) pairs: the paper-era
                        // synchronous re-gather vs the prefetch split
                        for (bb, om) in [(0.2, 0.3), (0.0, 0.5), (0.5, 0.0)] {
                            let inp = PipeInputs {
                                sched,
                                pp: p,
                                num_micro: m,
                                fwd_total: m as f64 * 0.9,
                                bwd_total: 2.0 * m as f64,
                                blocking_fwd_micro: 0.1,
                                blocking_bwd_micro: bb,
                                ovl_micro: om,
                                ovl_step: 0.4,
                                hop: 0.05,
                                overlap,
                            };
                            let tag = format!(
                                "{sched:?} p={p} m={m} overlap={overlap} bb={bb} om={om}"
                            );
                            let reference = simulate_pipeline_reference(&inp);
                            // cold (fresh skeleton + arena) and warm
                            // (global cache + thread-local arena) paths
                            let cold = simulate_pipeline_uncached(&inp);
                            assert_outcomes_bit_identical(&cold, &reference, &tag);
                            let warm = simulate_pipeline(&inp);
                            assert_outcomes_bit_identical(&warm, &reference, &tag);
                            // and a guaranteed cache hit re-run
                            let hit = simulate_pipeline(&inp);
                            assert_outcomes_bit_identical(&hit, &reference, &tag);
                        }
                    }
                }
            }
        }
    }

    /// The engine reproduces the textbook bubbles exactly on uniform
    /// tasks: GPipe/1F1B idle (p−1)(f+b), interleaved 1/v of that.
    #[test]
    fn bubbles_match_schedule_theory() {
        for (p, m) in [(4usize, 8usize), (4, 16), (8, 16), (2, 8)] {
            let ideal = 2.0 * m as f64;
            let theory = (p - 1) as f64 * 2.0;
            for sched in [PipeSchedule::OneFOneB, PipeSchedule::GPipe] {
                let o = run(sched, p, m);
                assert!(
                    (o.makespan - (ideal + theory)).abs() < 1e-9,
                    "{sched:?} p={p} m={m}: makespan {}",
                    o.makespan
                );
                assert!((o.bubble - theory).abs() < 1e-9);
            }
            let o = run(PipeSchedule::Interleaved1F1B, p, m);
            assert!(
                (o.bubble - theory / INTERLEAVE_DEGREE as f64).abs() < 1e-9,
                "interleaved p={p} m={m}: bubble {}",
                o.bubble
            );
        }
    }

    /// No deadlock and bounded in-flight for every (schedule, p, m) the
    /// planner can produce — including partial interleave groups (ghost
    /// padding) and asymmetric fwd/bwd durations with hop delays.
    #[test]
    fn deadlock_free_and_inflight_bounded_across_shapes() {
        for sched in [
            PipeSchedule::OneFOneB,
            PipeSchedule::GPipe,
            PipeSchedule::Interleaved1F1B,
        ] {
            for p in 2..=8usize {
                for m in [1usize, 2, 3, 5, 7, 8, 12, 13, 16, 33, 96] {
                    let mut inp = PipeInputs {
                        sched,
                        pp: p,
                        num_micro: m,
                        fwd_total: m as f64,
                        bwd_total: 2.0 * m as f64,
                        blocking_fwd_micro: 0.1,
                        blocking_bwd_micro: 0.2,
                        ovl_micro: 0.3,
                        ovl_step: 0.4,
                        hop: 0.05,
                        overlap: true,
                    };
                    let o = simulate_pipeline(&inp);
                    let bound = crate::parallel::live_microbatches(sched, p, m);
                    assert!(
                        o.peak_inflight <= bound,
                        "{sched:?} p={p} m={m}: peak {} > live bound {bound}",
                        o.peak_inflight
                    );
                    assert!(o.makespan.is_finite() && o.bubble >= 0.0);
                    // serializing the streams can never be faster
                    inp.overlap = false;
                    let ser = simulate_pipeline(&inp);
                    assert!(ser.makespan >= o.makespan - 1e-9);
                }
            }
        }
    }

    /// Hop delays surface as measured bubble, not exposed comm.
    #[test]
    fn hops_appear_as_idle() {
        let base = run(PipeSchedule::OneFOneB, 4, 8);
        let hopped = simulate_pipeline(&PipeInputs {
            sched: PipeSchedule::OneFOneB,
            pp: 4,
            num_micro: 8,
            fwd_total: 8.0,
            bwd_total: 8.0,
            blocking_fwd_micro: 0.0,
            blocking_bwd_micro: 0.0,
            ovl_micro: 0.0,
            ovl_step: 0.0,
            hop: 0.25,
            overlap: true,
        });
        assert!(hopped.bubble > base.bubble);
        assert_eq!(hopped.exposed_grad, 0.0);
    }

    /// Comm-stream work hides behind backward windows at the documented
    /// efficiency; leftovers extend the critical stage.
    #[test]
    fn comm_stream_drains_against_backward() {
        let small = simulate_pipeline(&PipeInputs {
            sched: PipeSchedule::OneFOneB,
            pp: 2,
            num_micro: 8,
            fwd_total: 8.0,
            bwd_total: 8.0,
            blocking_fwd_micro: 0.0,
            blocking_bwd_micro: 0.0,
            ovl_micro: 0.1,
            ovl_step: 0.0,
            hop: 0.0,
            overlap: true,
        });
        assert!(small.exposed_grad < 1e-9, "light traffic fully hides");
        let heavy = simulate_pipeline(&PipeInputs {
            sched: PipeSchedule::OneFOneB,
            pp: 2,
            num_micro: 8,
            fwd_total: 8.0,
            bwd_total: 8.0,
            blocking_fwd_micro: 0.0,
            blocking_bwd_micro: 0.0,
            ovl_micro: 4.0,
            ovl_step: 0.0,
            hop: 0.0,
            overlap: true,
        });
        // 32s of traffic vs 0.85·8s of backward windows (+ idle gaps)
        assert!(heavy.exposed_grad > 20.0);
        assert!(heavy.makespan > small.makespan);
    }

    /// Satellite: a skeleton-cache hit returns a bit-identical outcome to
    /// a cold miss, and the counters are exact.
    #[test]
    fn skeleton_cache_hit_bit_identical_to_miss() {
        let cache = SkeletonCache::with_capacity(8);
        let inp = PipeInputs {
            sched: PipeSchedule::Interleaved1F1B,
            pp: 4,
            num_micro: 13,
            fwd_total: 11.0,
            bwd_total: 23.0,
            blocking_fwd_micro: 0.07,
            blocking_bwd_micro: 0.11,
            ovl_micro: 0.13,
            ovl_step: 0.17,
            hop: 0.02,
            overlap: true,
        };
        let key = SkeletonKey::of(&inp);
        let mut scratch = TimelineScratch::new();
        let miss = simulate_pipeline_with(&cache.get(key), &mut scratch, &inp);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let hit = simulate_pipeline_with(&cache.get(key), &mut scratch, &inp);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_outcomes_bit_identical(&hit, &miss, "hit vs miss");
        assert_outcomes_bit_identical(&miss, &simulate_pipeline_uncached(&inp), "miss vs cold");
    }

    /// Satellite: eviction under a tiny capacity never changes results —
    /// alternating shapes through a 1-entry cache thrashes every lookup
    /// and still prices bit-identically to the uncached path.
    #[test]
    fn skeleton_eviction_never_changes_results() {
        let tiny = SkeletonCache::with_capacity(1);
        let mut scratch = TimelineScratch::new();
        let mk = |sched: PipeSchedule, p: usize, m: usize| PipeInputs {
            sched,
            pp: p,
            num_micro: m,
            fwd_total: m as f64,
            bwd_total: 1.7 * m as f64,
            blocking_fwd_micro: 0.03,
            blocking_bwd_micro: 0.05,
            ovl_micro: 0.08,
            ovl_step: 0.2,
            hop: 0.01,
            overlap: true,
        };
        let shapes = [
            mk(PipeSchedule::OneFOneB, 4, 9),
            mk(PipeSchedule::GPipe, 3, 7),
            mk(PipeSchedule::Interleaved1F1B, 2, 5),
        ];
        for round in 0..3 {
            for inp in &shapes {
                let skel = tiny.get(SkeletonKey::of(inp));
                let got = simulate_pipeline_with(&skel, &mut scratch, inp);
                let want = simulate_pipeline_uncached(inp);
                assert_outcomes_bit_identical(&got, &want, &format!("round {round}"));
                assert!(tiny.len() <= 1, "capacity bound violated: {}", tiny.len());
            }
        }
        // every distinct-shape lookup after the first round thrashed: the
        // 1-entry cache can never hold the next shape
        assert_eq!(tiny.hits(), 0);
        assert_eq!(tiny.misses(), 9);
    }

    /// The weight budget evicts heavy shapes even when the entry count
    /// is far from its bound, the accounting stays exact through evict
    /// and clear, and the newest skeleton always survives its own insert.
    #[test]
    fn skeleton_weight_budget_bounds_residency() {
        // every (1F1B, 2, 64) skeleton weighs 2*2*64 = 256 ids; budget 600
        // holds at most two of them
        let cache = SkeletonCache::with_budget(1024, 600);
        let key = |m: usize| SkeletonKey { sched: PipeSchedule::OneFOneB, pp: 2, num_micro: m };
        let w = cache.get(key(64)).weight();
        assert_eq!(w, 256);
        assert_eq!(cache.resident_weight(), 256);
        cache.get(key(65));
        cache.get(key(66));
        assert!(cache.len() <= 2, "weight budget must evict: {} resident", cache.len());
        assert!(cache.resident_weight() <= 600);
        // the newest shape is resident (its re-get is a hit)...
        let h = cache.hits();
        cache.get(key(66));
        assert_eq!(cache.hits(), h + 1);
        // ...and a single over-budget skeleton still caches (never evicts
        // down to empty)
        let big = SkeletonCache::with_budget(1024, 100);
        big.get(key(64));
        assert_eq!(big.len(), 1);
        let h = big.hits();
        big.get(key(64));
        assert_eq!(big.hits(), h + 1);
        big.clear();
        assert_eq!(big.resident_weight(), 0);
        assert_eq!(big.len(), 0);
    }

    /// Satellite: concurrent hits from 8 threads keep the counters exact
    /// (misses == distinct keys; every other lookup is a hit), and all
    /// threads read the same shared skeleton.
    #[test]
    fn skeleton_cache_counters_exact_under_contention() {
        let cache = SkeletonCache::with_capacity(64);
        let keys: Vec<SkeletonKey> = (1..=4usize)
            .flat_map(|p| {
                [PipeSchedule::OneFOneB, PipeSchedule::GPipe].into_iter().map(move |sched| {
                    SkeletonKey { sched, pp: p, num_micro: 6 }
                })
            })
            .collect();
        let per_thread = 100usize;
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let keys = &keys;
                let cache = &cache;
                scope.spawn(move || {
                    for i in 0..per_thread {
                        let skel = cache.get(keys[i % keys.len()]);
                        assert_eq!(skel.key(), keys[i % keys.len()]);
                    }
                });
            }
        });
        assert_eq!(cache.misses(), keys.len());
        assert_eq!(cache.hits(), 8 * per_thread - keys.len());
        assert_eq!(cache.len(), keys.len());
        assert!(cache.hit_rate() > 0.9);
    }

    /// Satellite: the no-allocation smoke — once an arena has seen a
    /// shape, re-simulating it clears the arena without growing any
    /// backing buffer (counting clears, not allocations, stays portable
    /// across allocators).
    #[test]
    fn steady_state_scratch_never_grows() {
        let mut scratch = TimelineScratch::new();
        let skel = PipeSkeleton::build(SkeletonKey {
            sched: PipeSchedule::Interleaved1F1B,
            pp: 4,
            num_micro: 11,
        });
        let inp = PipeInputs {
            sched: PipeSchedule::Interleaved1F1B,
            pp: 4,
            num_micro: 11,
            fwd_total: 11.0,
            bwd_total: 22.0,
            blocking_fwd_micro: 0.1,
            blocking_bwd_micro: 0.2,
            ovl_micro: 0.3,
            ovl_step: 0.4,
            hop: 0.05,
            overlap: true,
        };
        let _ = simulate_pipeline_with(&skel, &mut scratch, &inp);
        let _ = simulate_pipeline_with(&skel, &mut scratch, &inp);
        let (clears, grows) = scratch.stats();
        assert_eq!(clears, 2);
        for i in 0..100u64 {
            let _ = simulate_pipeline_with(&skel, &mut scratch, &inp);
            let (c, g) = scratch.stats();
            assert_eq!(c, clears + 1 + i, "every call clears the arena");
            assert_eq!(g, grows, "steady state must not grow any buffer");
        }
        // a *smaller* shape reuses the buffers without growth either
        let small_key =
            SkeletonKey { sched: PipeSchedule::OneFOneB, pp: 2, num_micro: 3 };
        let small = PipeSkeleton::build(small_key);
        let small_inp = PipeInputs { sched: PipeSchedule::OneFOneB, pp: 2, num_micro: 3, ..inp };
        let (_, g_before) = scratch.stats();
        let _ = simulate_pipeline_with(&small, &mut scratch, &small_inp);
        assert_eq!(scratch.stats().1, g_before, "shrinking shapes must not allocate");
    }

    /// Satellite: per-micro-batch jitter.  `spread = 0` is the
    /// deterministic engine bit for bit; a positive spread perturbs the
    /// makespan, reproduces exactly for the same `(seed, sample)`, and
    /// every per-task factor stays inside the clamped spread band.
    #[test]
    fn jitter_zero_spread_bit_identical_and_samples_reproduce() {
        let inp = PipeInputs {
            sched: PipeSchedule::OneFOneB,
            pp: 4,
            num_micro: 12,
            fwd_total: 12.0,
            bwd_total: 24.0,
            blocking_fwd_micro: 0.1,
            blocking_bwd_micro: 0.2,
            ovl_micro: 0.3,
            ovl_step: 0.4,
            hop: 0.05,
            overlap: true,
        };
        let base = simulate_pipeline(&inp);
        let zero = simulate_pipeline_jittered(&inp, 42, 7, 0.0);
        assert_outcomes_bit_identical(&zero, &base, "spread 0 degenerates");
        let neg = simulate_pipeline_jittered(&inp, 42, 7, -1.0);
        assert_outcomes_bit_identical(&neg, &base, "negative spread degenerates");
        let j1 = simulate_pipeline_jittered(&inp, 42, 7, 0.3);
        let j1b = simulate_pipeline_jittered(&inp, 42, 7, 0.3);
        assert_outcomes_bit_identical(&j1, &j1b, "same (seed, sample) reproduces");
        assert!(j1.makespan.is_finite() && j1.makespan > 0.0);
        let j2 = simulate_pipeline_jittered(&inp, 42, 8, 0.3);
        assert_ne!(
            j1.makespan.to_bits(),
            j2.makespan.to_bits(),
            "distinct samples draw distinct traces"
        );
        // per-task factors live in [1 - spread, 1 + spread] ...
        let j = TaskJitter::new(1, 2, 0.3);
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for t in 0..4096u64 {
            let f = j.factor(t);
            assert!((0.7..=1.3).contains(&f), "factor {f} escapes the band");
            lo = lo.min(f);
            hi = hi.max(f);
        }
        // ... and actually fill it (the hash is not degenerate)
        assert!(lo < 0.75 && hi > 1.25, "factors collapsed: [{lo}, {hi}]");
        // wild spreads clamp so factors stay positive
        let wild = TaskJitter::new(1, 2, 7.0);
        for t in 0..4096u64 {
            assert!(wild.factor(t) > 0.0);
        }
    }

    /// The drain budget is linear in the step time, never negative, and
    /// strictly below a full step (the coefficient protects the interval
    /// optimizer's monotonicity contract).
    #[test]
    fn drain_budget_linear_and_below_one_step() {
        assert_eq!(checkpoint_drain_budget(0.0), 0.0);
        assert_eq!(checkpoint_drain_budget(-5.0), 0.0);
        let b1 = checkpoint_drain_budget(1.0);
        assert!((b1 - OVERLAP_EFFICIENCY * 2.0 / 3.0).abs() < 1e-15);
        assert!(b1 < 1.0);
        assert_eq!(checkpoint_drain_budget(10.0).to_bits(), (b1 * 10.0).to_bits());
    }

    /// The thread-local arena behind [`simulate_pipeline`] reaches the
    /// same steady state: warm calls advance clears, not grows.
    #[test]
    fn thread_local_arena_steady_state() {
        let inp = PipeInputs {
            sched: PipeSchedule::GPipe,
            pp: 3,
            num_micro: 10,
            fwd_total: 10.0,
            bwd_total: 20.0,
            blocking_fwd_micro: 0.1,
            blocking_bwd_micro: 0.2,
            ovl_micro: 0.3,
            ovl_step: 0.4,
            hop: 0.05,
            overlap: true,
        };
        let _ = simulate_pipeline(&inp);
        let (c0, g0) = scratch_stats();
        for _ in 0..20 {
            let _ = simulate_pipeline(&inp);
        }
        let (c1, g1) = scratch_stats();
        assert_eq!(c1, c0 + 20);
        assert_eq!(g1, g0, "warm thread-local arena must not grow");
    }
}
