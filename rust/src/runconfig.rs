//! Run configuration files: the launcher's TOML interface.
//!
//! `scalestudy train --config run.toml` materializes a [`TrainerCfg`] +
//! run geometry from a TOML file (parsed by [`crate::configtoml`] into
//! the crate's JSON value tree).  Example (see `examples/configs/`):
//!
//! ```toml
//! preset = "tiny"
//! steps = 300
//!
//! [trainer]
//! ranks = 4
//! zero_stage = 1
//! seed = 42
//! loader_workers = 2
//! grad_clip = 1.0
//!
//! [optimizer]
//! kind = "adamw"          # adamw | sgd
//! weight_decay = 0.01
//!
//! [schedule]
//! kind = "invsqrt"        # constant | linear | invsqrt
//! peak = 8e-3
//! warmup = 50
//! ```

use crate::json::Json;
use crate::train::{LrSchedule, Optimizer, TrainerCfg};
use anyhow::{bail, Result};

/// A full run description: what to train and how.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub preset: String,
    pub steps: u64,
    pub trainer: TrainerCfg,
    /// Optional CSV output path.
    pub csv: Option<String>,
    /// Optional checkpoint save directory.
    pub save: Option<String>,
}

impl RunConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let j = crate::configtoml::parse(text)?;
        Self::from_value(&j)
    }

    /// Parse from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    fn from_value(j: &Json) -> Result<RunConfig> {
        let preset = j
            .get("preset")
            .as_str()
            .unwrap_or("tiny")
            .to_string();
        let steps = j.get("steps").as_usize().unwrap_or(100) as u64;

        let t = j.get("trainer");
        let mut cfg = TrainerCfg {
            ranks: t.get("ranks").as_usize().unwrap_or(4),
            zero_stage: t.get("zero_stage").as_usize().unwrap_or(1),
            seed: t.get("seed").as_usize().unwrap_or(42) as u64,
            loader_workers: t.get("loader_workers").as_usize().unwrap_or(1),
            grad_clip: t.get("grad_clip").as_f64().unwrap_or(1.0) as f32,
            ..TrainerCfg::default()
        };
        if cfg.ranks == 0 {
            bail!("trainer.ranks must be >= 1");
        }
        if cfg.zero_stage > 1 {
            bail!("trainer.zero_stage must be 0 or 1 for the executable trainer");
        }

        let o = j.get("optimizer");
        cfg.optimizer = match o.get("kind").as_str().unwrap_or("adamw") {
            "adamw" => {
                let mut opt = Optimizer::adamw();
                if let Optimizer::AdamW { ref mut weight_decay, ref mut beta1, ref mut beta2, .. } =
                    opt
                {
                    if let Some(wd) = o.get("weight_decay").as_f64() {
                        *weight_decay = wd as f32;
                    }
                    if let Some(b) = o.get("beta1").as_f64() {
                        *beta1 = b as f32;
                    }
                    if let Some(b) = o.get("beta2").as_f64() {
                        *beta2 = b as f32;
                    }
                }
                opt
            }
            "sgd" => Optimizer::sgd(o.get("momentum").as_f64().unwrap_or(0.9) as f32),
            k => bail!("unknown optimizer.kind '{k}' (adamw|sgd)"),
        };

        let s = j.get("schedule");
        let peak = s.get("peak").as_f64().unwrap_or(8e-3) as f32;
        let warmup = s.get("warmup").as_usize().unwrap_or(50) as u64;
        cfg.schedule = match s.get("kind").as_str().unwrap_or("invsqrt") {
            "constant" => LrSchedule::Constant { lr: peak },
            "linear" => LrSchedule::LinearWarmupDecay {
                peak,
                warmup,
                total_steps: s
                    .get("total_steps")
                    .as_usize()
                    .map(|x| x as u64)
                    .unwrap_or(steps + steps / 5),
            },
            "invsqrt" => LrSchedule::InvSqrt { peak, warmup },
            k => bail!("unknown schedule.kind '{k}' (constant|linear|invsqrt)"),
        };

        Ok(RunConfig {
            preset,
            steps,
            trainer: cfg,
            csv: j.get("csv").as_str().map(|s| s.to_string()),
            save: j.get("save").as_str().map(|s| s.to_string()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
preset = "micro"
steps = 25
csv = "/tmp/run.csv"

[trainer]
ranks = 3
zero_stage = 0
seed = 7
loader_workers = 2
grad_clip = 0.5

[optimizer]
kind = "adamw"
weight_decay = 0.1
beta1 = 0.85

[schedule]
kind = "linear"
peak = 1e-3
warmup = 10
total_steps = 40
"#;

    #[test]
    fn full_config_parses() {
        let rc = RunConfig::from_toml(FULL).unwrap();
        assert_eq!(rc.preset, "micro");
        assert_eq!(rc.steps, 25);
        assert_eq!(rc.csv.as_deref(), Some("/tmp/run.csv"));
        assert_eq!(rc.trainer.ranks, 3);
        assert_eq!(rc.trainer.zero_stage, 0);
        assert_eq!(rc.trainer.seed, 7);
        assert!((rc.trainer.grad_clip - 0.5).abs() < 1e-9);
        match rc.trainer.optimizer {
            Optimizer::AdamW { beta1, weight_decay, .. } => {
                assert!((beta1 - 0.85).abs() < 1e-6);
                assert!((weight_decay - 0.1).abs() < 1e-6);
            }
            _ => panic!("expected adamw"),
        }
        match rc.trainer.schedule {
            LrSchedule::LinearWarmupDecay { peak, warmup, total_steps } => {
                assert!((peak - 1e-3).abs() < 1e-9);
                assert_eq!(warmup, 10);
                assert_eq!(total_steps, 40);
            }
            _ => panic!("expected linear schedule"),
        }
    }

    #[test]
    fn defaults_fill_missing_sections() {
        let rc = RunConfig::from_toml("preset = \"tiny\"").unwrap();
        assert_eq!(rc.preset, "tiny");
        assert_eq!(rc.steps, 100);
        assert_eq!(rc.trainer.ranks, 4);
        assert!(matches!(rc.trainer.schedule, LrSchedule::InvSqrt { .. }));
        assert!(rc.csv.is_none());
    }

    #[test]
    fn sgd_config() {
        let rc = RunConfig::from_toml(
            "preset = \"micro\"\n[optimizer]\nkind = \"sgd\"\nmomentum = 0.8",
        )
        .unwrap();
        assert_eq!(rc.trainer.optimizer, Optimizer::sgd(0.8));
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(RunConfig::from_toml("[trainer]\nranks = 0").is_err());
        assert!(RunConfig::from_toml("[trainer]\nzero_stage = 3").is_err());
        assert!(RunConfig::from_toml("[optimizer]\nkind = \"rmsprop\"").is_err());
        assert!(RunConfig::from_toml("[schedule]\nkind = \"cyclic\"").is_err());
        assert!(RunConfig::from_toml("not toml at all").is_err());
    }
}
