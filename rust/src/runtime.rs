//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from Rust.  Python never runs here — the HLO text is
//! parsed, compiled and executed by the XLA CPU PJRT client behind the
//! `xla` crate (see /opt/xla-example/load_hlo for the reference wiring).
//!
//! Artifact contract (one per model preset):
//! * `<preset>_train.hlo.txt` — `(params..., enc, dec, tgt) -> (loss, grads...)`
//! * `<preset>_eval.hlo.txt`  — `(params..., enc, dec, tgt) -> (loss,)`
//! * `<preset>_manifest.json` — parameter names/shapes/init-stds in the
//!   exact positional order of the HLO signature, plus batch geometry.
//! * `adamw_<chunk>.hlo.txt`  — fused AdamW over flat f32[chunk].

use crate::json::Json;
use crate::util::Rng;
// Without the real PJRT bindings the API-compatible stub stands in; the
// artifact-driven integration tests are gated on the `pjrt` feature.
use crate::xla;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One parameter tensor's metadata.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    pub init_std: f32,
}

/// Parsed `<preset>_manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub params: Vec<ParamSpec>,
    pub total_params: usize,
    pub batch_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
    pub pad_id: i32,
    pub vocab: usize,
    pub train_artifact: String,
    pub eval_artifact: String,
    pub adamw_artifact: String,
    pub adamw_chunk: usize,
}

impl Manifest {
    pub fn load(dir: &Path, preset: &str) -> Result<Manifest> {
        let path = dir.join(format!("{preset}_manifest.json"));
        let j = Json::parse_file(&path).context("loading manifest")?;
        let params = j
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name").as_str().unwrap_or_default().to_string(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow!("param missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    size: p.get("size").as_usize().unwrap_or(0),
                    init_std: p.get("init_std").as_f64().unwrap_or(0.02) as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            preset: j.get("preset").as_str().unwrap_or(preset).to_string(),
            total_params: j.get("total_params").as_usize().unwrap_or(0),
            batch_size: j.path(&["batch", "size"]).as_usize().unwrap_or(0),
            enc_len: j.path(&["batch", "enc_len"]).as_usize().unwrap_or(0),
            dec_len: j.path(&["batch", "dec_len"]).as_usize().unwrap_or(0),
            pad_id: j.get("pad_id").as_i64().unwrap_or(0) as i32,
            vocab: j.path(&["config", "vocab"]).as_usize().unwrap_or(0),
            train_artifact: j.get("train_artifact").as_str().unwrap_or_default().to_string(),
            eval_artifact: j.get("eval_artifact").as_str().unwrap_or_default().to_string(),
            adamw_artifact: j.get("adamw_artifact").as_str().unwrap_or_default().to_string(),
            adamw_chunk: j.get("adamw_chunk").as_usize().unwrap_or(65536),
            params,
        })
    }

    /// Sum of parameter sizes — must equal `total_params`.
    pub fn flat_len(&self) -> usize {
        self.params.iter().map(|p| p.size).sum()
    }

    /// (offset, size) of each tensor in the flat parameter vector.
    pub fn flat_layout(&self) -> Vec<(usize, usize)> {
        let mut off = 0;
        self.params
            .iter()
            .map(|p| {
                let o = off;
                off += p.size;
                (o, p.size)
            })
            .collect()
    }

    /// Initialize a flat parameter vector exactly like
    /// `model.init_params` does in python (normals scaled by init_std;
    /// norm scales start at 1).  The PRNG differs from jax's — initial
    /// *distributions* match, not bits; trainability is what the e2e
    /// tests verify.
    pub fn init_flat(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut flat = Vec::with_capacity(self.flat_len());
        for p in &self.params {
            // RMSNorm scales ("…/norm", "final/enc_norm", "final/dec_norm")
            if p.name.ends_with("norm") {
                flat.extend(std::iter::repeat(1.0f32).take(p.size));
            } else {
                for _ in 0..p.size {
                    flat.push(rng.normal_f32(p.init_std));
                }
            }
        }
        flat
    }
}

/// A compiled HLO module.
pub struct Module {
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client, many compiled modules.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// CPU PJRT client over the artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;
        Ok(Runtime { client, dir: artifacts_dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact by file name.
    pub fn load(&self, file: &str) -> Result<Module> {
        let path = self.dir.join(file);
        if !path.exists() {
            bail!("artifact {} not found — run `make artifacts` first", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Module { exe })
    }
}

impl Module {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e}"))
    }
}

pub(crate) fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape{shape:?}: {e}"))
}

pub(crate) fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape{shape:?}: {e}"))
}

/// One tokenized batch in the artifact's geometry.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub enc: Vec<i32>,
    pub dec_in: Vec<i32>,
    pub targets: Vec<i32>,
}

/// The train-step module: `(params…, enc, dec, tgt) -> (loss, grads…)`,
/// operating on the flat parameter vector.
///
/// Input literals are allocated once and refreshed in place each step via
/// `copy_raw_from` (≈30 MB of allocator traffic per step avoided on the
/// `tiny` preset; see EXPERIMENTS.md §Perf L3).
pub struct TrainModule {
    pub manifest: Manifest,
    module: Module,
    inputs: std::cell::RefCell<Vec<xla::Literal>>,
}

impl TrainModule {
    pub fn load(rt: &Runtime, manifest: &Manifest) -> Result<TrainModule> {
        // pre-allocate the input literals (zeros) with the final shapes
        let mut inputs = Vec::with_capacity(manifest.params.len() + 3);
        for spec in &manifest.params {
            inputs.push(lit_f32(&vec![0.0; spec.size], &spec.shape)?);
        }
        let be = manifest.batch_size * manifest.enc_len;
        let bd = manifest.batch_size * manifest.dec_len;
        inputs.push(lit_i32(&vec![0; be], &[manifest.batch_size, manifest.enc_len])?);
        inputs.push(lit_i32(&vec![0; bd], &[manifest.batch_size, manifest.dec_len])?);
        inputs.push(lit_i32(&vec![0; bd], &[manifest.batch_size, manifest.dec_len])?);
        Ok(TrainModule {
            manifest: manifest.clone(),
            module: rt.load(&manifest.train_artifact)?,
            inputs: std::cell::RefCell::new(inputs),
        })
    }

    /// Run one step: returns (loss, flat gradient vector).
    pub fn step(&self, flat_params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        let mut grads = vec![0.0f32; self.manifest.flat_len()];
        let loss = self.step_into(flat_params, batch, &mut grads)?;
        Ok((loss, grads))
    }

    /// Allocation-light variant: writes gradients into a caller buffer
    /// and refreshes the cached input literals in place.
    pub fn step_into(
        &self,
        flat_params: &[f32],
        batch: &Batch,
        grads_out: &mut [f32],
    ) -> Result<f32> {
        let m = &self.manifest;
        assert_eq!(flat_params.len(), m.flat_len(), "flat param length");
        assert_eq!(grads_out.len(), m.flat_len(), "grad buffer length");
        let mut inputs = self.inputs.borrow_mut();
        let np = m.params.len();
        for (i, (off, size)) in m.flat_layout().into_iter().enumerate() {
            inputs[i]
                .copy_raw_from(&flat_params[off..off + size])
                .map_err(|e| anyhow!("param upload: {e}"))?;
        }
        inputs[np].copy_raw_from(&batch.enc).map_err(|e| anyhow!("enc upload: {e}"))?;
        inputs[np + 1].copy_raw_from(&batch.dec_in).map_err(|e| anyhow!("dec upload: {e}"))?;
        inputs[np + 2].copy_raw_from(&batch.targets).map_err(|e| anyhow!("tgt upload: {e}"))?;

        let out = self.module.run(&inputs)?;
        if out.len() != 1 + m.params.len() {
            bail!("train artifact returned {} outputs, want {}", out.len(), 1 + m.params.len());
        }
        let loss = out[0]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss readback: {e}"))?;
        for ((_, (off, size)), lit) in m.params.iter().zip(m.flat_layout()).zip(&out[1..]) {
            lit.copy_raw_to(&mut grads_out[off..off + size])
                .map_err(|e| anyhow!("grad readback: {e}"))?;
        }
        Ok(loss)
    }
}

/// The eval module: loss only.
pub struct EvalModule {
    pub manifest: Manifest,
    module: Module,
}

impl EvalModule {
    pub fn load(rt: &Runtime, manifest: &Manifest) -> Result<EvalModule> {
        Ok(EvalModule { manifest: manifest.clone(), module: rt.load(&manifest.eval_artifact)? })
    }

    pub fn loss(&self, flat_params: &[f32], batch: &Batch) -> Result<f32> {
        let m = &self.manifest;
        let mut inputs = Vec::with_capacity(m.params.len() + 3);
        for (spec, (off, size)) in m.params.iter().zip(m.flat_layout()) {
            inputs.push(lit_f32(&flat_params[off..off + size], &spec.shape)?);
        }
        inputs.push(lit_i32(&batch.enc, &[m.batch_size, m.enc_len])?);
        inputs.push(lit_i32(&batch.dec_in, &[m.batch_size, m.dec_len])?);
        inputs.push(lit_i32(&batch.targets, &[m.batch_size, m.dec_len])?);
        let out = self.module.run(&inputs)?;
        out[0]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss readback: {e}"))
    }
}

/// The fused-AdamW module over fixed-size flat chunks
/// (`adamw_<chunk>.hlo.txt`): `(p, g, m, v, step, lr, wd) -> (p', m', v')`.
pub struct AdamWModule {
    module: Module,
    pub chunk: usize,
}

impl AdamWModule {
    pub fn load(rt: &Runtime, manifest: &Manifest) -> Result<AdamWModule> {
        Ok(AdamWModule { module: rt.load(&manifest.adamw_artifact)?, chunk: manifest.adamw_chunk })
    }

    /// Apply the update in place over `p`, `m`, `v` (zero-padding the
    /// tail chunk).
    pub fn update(
        &self,
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        step: f32,
        lr: f32,
        weight_decay: f32,
    ) -> Result<()> {
        let n = p.len();
        let c = self.chunk;
        let mut buf_p = vec![0.0f32; c];
        let mut buf_g = vec![0.0f32; c];
        let mut buf_m = vec![0.0f32; c];
        let mut buf_v = vec![0.0f32; c];
        let mut tmp = vec![0.0f32; c];
        let mut off = 0;
        while off < n {
            let len = c.min(n - off);
            buf_p[..len].copy_from_slice(&p[off..off + len]);
            buf_g[..len].copy_from_slice(&g[off..off + len]);
            buf_m[..len].copy_from_slice(&m[off..off + len]);
            buf_v[..len].copy_from_slice(&v[off..off + len]);
            if len < c {
                for b in [&mut buf_p, &mut buf_g, &mut buf_m, &mut buf_v] {
                    b[len..].fill(0.0);
                }
            }
            let inputs = [
                lit_f32(&buf_p, &[c])?,
                lit_f32(&buf_g, &[c])?,
                lit_f32(&buf_m, &[c])?,
                lit_f32(&buf_v, &[c])?,
                lit_f32(&[step], &[1])?,
                xla::Literal::scalar(lr),
                xla::Literal::scalar(weight_decay),
            ];
            let out = self.module.run(&inputs)?;
            if out.len() != 3 {
                bail!("adamw artifact returned {} outputs", out.len());
            }
            out[0].copy_raw_to(&mut tmp).map_err(|e| anyhow!("{e}"))?;
            p[off..off + len].copy_from_slice(&tmp[..len]);
            out[1].copy_raw_to(&mut tmp).map_err(|e| anyhow!("{e}"))?;
            m[off..off + len].copy_from_slice(&tmp[..len]);
            out[2].copy_raw_to(&mut tmp).map_err(|e| anyhow!("{e}"))?;
            v[off..off + len].copy_from_slice(&tmp[..len]);
            off += len;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> String {
        r#"{
  "preset": "t",
  "config": {"vocab": 64, "d_model": 8, "d_ff": 16, "num_heads": 2,
             "enc_layers": 1, "dec_layers": 1},
  "batch": {"size": 2, "enc_len": 4, "dec_len": 4},
  "pad_id": 0,
  "num_params_tensors": 2,
  "total_params": 520,
  "params": [
    {"name": "embed/token", "shape": [64, 8], "init_std": 1.0, "size": 512},
    {"name": "final/enc_norm", "shape": [8], "init_std": 0.0, "size": 8}
  ],
  "train_artifact": "t_train.hlo.txt",
  "eval_artifact": "t_eval.hlo.txt",
  "adamw_artifact": "adamw_65536.hlo.txt",
  "adamw_chunk": 65536
}"#
        .to_string()
    }

    #[test]
    fn manifest_parses_and_layout_consistent() {
        let dir = std::env::temp_dir().join("scalestudy_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t_manifest.json"), manifest_json()).unwrap();
        let m = Manifest::load(&dir, "t").unwrap();
        assert_eq!(m.preset, "t");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.flat_len(), 520);
        assert_eq!(m.total_params, 520);
        assert_eq!(m.flat_layout(), vec![(0, 512), (512, 8)]);
        assert_eq!(m.batch_size, 2);
        assert_eq!(m.vocab, 64);
    }

    #[test]
    fn init_flat_norms_are_ones_and_weights_scaled() {
        let dir = std::env::temp_dir().join("scalestudy_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t_manifest.json"), manifest_json()).unwrap();
        let m = Manifest::load(&dir, "t").unwrap();
        let flat = m.init_flat(7);
        assert_eq!(flat.len(), 520);
        // norm scale tensor is all ones
        assert!(flat[512..].iter().all(|&x| x == 1.0));
        // embedding init has roughly unit std
        let emb = &flat[..512];
        let mean: f32 = emb.iter().sum::<f32>() / 512.0;
        let var: f32 = emb.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 511.0;
        assert!(mean.abs() < 0.2, "{mean}");
        assert!((var.sqrt() - 1.0).abs() < 0.2, "{var}");
        // determinism
        assert_eq!(flat, m.init_flat(7));
        assert_ne!(flat, m.init_flat(8));
    }

    #[test]
    fn missing_artifact_reports_helpfully() {
        let dir = std::env::temp_dir().join("scalestudy_missing_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let rt = Runtime::cpu(&dir).unwrap();
        let err = match rt.load("nope.hlo.txt") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("load of a missing artifact must fail"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }
}
